"""PG-Fuse — large-block caching file layer (paper §III).

The paper observes that the Java WebGraph reader issues frequent small
(<=128 kB) reads, under-utilizing high-bandwidth storage (SSD pools, Lustre)
and defeating read-ahead prefetchers.  PG-Fuse interposes a *filesystem in
user space* that (i) enlarges requested blocks (default **32 MiB**),
(ii) reduces the number of calls into the underlying filesystem, and
(iii) caches received blocks in memory for future calls.

Hardware adaptation (DESIGN.md §2): inside a managed TPU pod we cannot (and
need not) mount a kernel VFS layer, so the interposition point moves from
FUSE/VFS to the loader's file abstraction: :class:`CachedFile` implements
the same ``pread``/file interface every consumer in this framework uses
(CompBin reader, WebGraph reader, token-shard reader), which preserves the
paper's independence argument — the consumer is unmodified.

Block state machine (paper Fig. 1), one integer status per block, all
transitions via compare-and-swap:

      0   loaded and accessible (idle)
      >0  number of concurrent reader threads (counter)
     -1   not loaded
     -2   a thread is loading the block; others must wait
     -3   the block is being revoked (eviction by last-access time)

Transitions::

     -1 --cas--> -2 --load--> 1 --release--> 0 --acquire--> 1,2,3,...
      0 --cas--> -3 --free--> -1

Replacement policy (access-pattern split): sequential scans want pure
recency (LRU) — every block is touched once and never again, so evicting
the oldest is exact.  Random adjacency queries ("Making Caches Work for
Graph Analytics", arXiv:1608.01362) break that assumption: the hot set
(offset-array blocks, high-degree hubs) is re-touched at irregular
intervals and a strict recency order evicts it whenever one large batch
touches many cold packed-byte blocks in between.  ``eviction="clock"``
keeps a second-chance reference bit per block instead: a sweep clears
bits before revoking, so any block re-touched since the last sweep
survives the batch churn.  ``CachedFile(max_resident_bytes=...)`` adds a
per-file cap on top of the mount-wide budget, bounding how much of the
shared budget one file's churn may claim (e.g. cap the packed-neighbor /
feature-store traffic so the hot offset blocks are never the victims).

Multi-tenant shares: several serving models on ONE mount group their
files into :class:`EngineShare` slices
(``fs.register_engine("model-a", budget)``; files join via
``share.mount`` / ``fs.mount(path, engine=...)``).  A share is both a
cap and a reservation layered over the per-file budgets: a share over
its budget reclaims from its OWN files first (biggest resident first,
each file's clock hand supplying second chances), and the mount-wide
sweep protects every share still inside its budget — so one tenant's
churn can never evict another tenant's warm set, only its own.

In the serving stack this layer is the MIDDLE tier of the three-tier
cache hierarchy (docs/architecture.md): storage blocks below it, and
above it the HBM-resident hot set of *decoded* neighbor runs
(:class:`repro.query.HotSetCache`) — a hot-set hit skips PG-Fuse
entirely; a miss lands here as packed-byte block reads.
"""

from __future__ import annotations

import dataclasses
import os
import threading
import time
from typing import BinaryIO, Dict, Optional, Union

import numpy as np

from repro.obs.trace import NULL_TRACER as _NULL_TRACER

# Block states (paper Fig. 1)
LOADED = 0        # >= 0: reader count
NOT_LOADED = -1
LOADING = -2
REVOKING = -3

DEFAULT_BLOCK_SIZE = 32 * 2**20  # 32 MiB (paper §III)

# Replacement policies (choose via core.policy.choose_access_mode)
EVICT_LRU = "lru"          # exact recency order — sequential scans
EVICT_CLOCK = "clock"      # second-chance ref bits — random access
EVICTION_POLICIES = (EVICT_LRU, EVICT_CLOCK)


@dataclasses.dataclass
class PGFuseStats:
    underlying_reads: int = 0      # calls into the underlying filesystem
    underlying_bytes: int = 0      # bytes fetched from it
    cache_hits: int = 0            # block acquisitions served from memory
    cache_misses: int = 0          # block acquisitions that triggered a load
    waits: int = 0                 # acquisitions that had to wait (-2/-3)
    evictions: int = 0             # blocks revoked
    bytes_served: int = 0          # bytes returned to consumers
    readahead_blocks: int = 0      # blocks loaded ahead of any request
    span_fetch_blocks: int = 0     # blocks installed by prefetch_range
                                   # (consumer-announced spans, one
                                   # enlarged request per NOT_LOADED run)
    retried_reads: int = 0         # transient-fault retries that went back
                                   # to storage (see CachedFile retries=)

    def merge(self, other: "PGFuseStats") -> None:
        for f in dataclasses.fields(self):
            setattr(self, f.name, getattr(self, f.name) + getattr(other, f.name))

    def as_dict(self) -> dict:
        """Fields + the derived block-cache ``hit_rate`` — the surface
        registered under the ``pgfuse.*`` metric namespace
        (``repro.obs.metrics.NAMESPACE``; drift-checked in CI)."""
        d = dataclasses.asdict(self)
        n = d["cache_hits"] + d["cache_misses"]
        d["hit_rate"] = d["cache_hits"] / n if n else 0.0
        return d


class _StatusArray:
    """CAS-protected per-block status words.

    The paper uses C atomics; under the GIL we realize the identical
    transition diagram with striped mutexes guarding a numpy int64 array —
    every state change goes through :meth:`cas`, so the diagram of Fig. 1 is
    enforced verbatim (stress-tested in tests/test_pgfuse.py).
    """

    N_STRIPES = 64

    def __init__(self, n_blocks: int):
        self._status = np.full(n_blocks, NOT_LOADED, dtype=np.int64)
        self._locks = [threading.Lock() for _ in range(self.N_STRIPES)]

    def load(self, i: int) -> int:
        return int(self._status[i])

    def cas(self, i: int, expected: int, new: int) -> bool:
        with self._locks[i % self.N_STRIPES]:
            if self._status[i] == expected:
                self._status[i] = new
                return True
            return False

    def add_reader(self, i: int) -> bool:
        """Atomically increment a non-negative status (0->1, n->n+1)."""
        with self._locks[i % self.N_STRIPES]:
            s = int(self._status[i])
            if s >= 0:
                self._status[i] = s + 1
                return True
            return False

    def release_reader(self, i: int) -> int:
        with self._locks[i % self.N_STRIPES]:
            s = int(self._status[i])
            assert s >= 1, f"release on block {i} in state {s}"
            self._status[i] = s - 1
            return s - 1

    def snapshot(self) -> np.ndarray:
        return self._status.copy()


class CachedFile:
    """One file's block cache; shared by any number of reader handles."""

    def __init__(self, path: Union[str, os.PathLike], *,
                 block_size: int = DEFAULT_BLOCK_SIZE,
                 fs: Optional["PGFuseFS"] = None,
                 pread_fn=None,
                 readahead: int = 0,
                 eviction: str = EVICT_LRU,
                 max_resident_bytes: Optional[int] = None,
                 retries: int = 0,
                 retry_backoff_s: float = 0.005,
                 clock=None):
        self.path = os.fspath(path)
        self.block_size = int(block_size)
        self.readahead = int(readahead)
        if self.block_size <= 0:
            raise ValueError("block_size must be positive")
        if self.readahead < 0:
            raise ValueError("readahead must be >= 0")
        if eviction not in EVICTION_POLICIES:
            raise ValueError(f"eviction must be one of {EVICTION_POLICIES}, "
                             f"got {eviction!r}")
        if retries < 0:
            raise ValueError("retries must be >= 0")
        self.eviction = eviction
        # per-FILE resident cap (on top of any mount-wide budget): bounds
        # how much cache this file's traffic may claim, so one file's
        # churn cannot evict another file's hot blocks
        self.max_resident_bytes = max_resident_bytes
        self.retries = int(retries)
        self.retry_backoff_s = float(retry_backoff_s)
        # last-access timestamps come from an injectable clock so eviction
        # order (and the multi-tenant soak tests that pin it) can be a
        # deterministic property of the access sequence, not of wall time
        self._clock = clock or time.monotonic
        # multi-tenant slice this file belongs to (PGFuseFS.register_engine)
        self.share: Optional["EngineShare"] = None
        self._fd = os.open(self.path, os.O_RDONLY)
        self.size = os.fstat(self._fd).st_size
        # injectable storage backend (benchmarks emulate Lustre/HDD
        # latency+bandwidth through here); default: the real filesystem
        self._pread_fn = pread_fn or (lambda fd, n, off: os.pread(fd, n, off))
        self.n_blocks = max(1, -(-self.size // self.block_size))
        self._statuses = _StatusArray(self.n_blocks)
        self._blocks: list[Optional[bytes]] = [None] * self.n_blocks
        # index of blocks with data installed, so eviction scans O(resident)
        # candidates instead of O(n_blocks) — release_block runs this on
        # every call when the cache sits at its budget (the streaming
        # loader's steady state)
        self._resident_set: set[int] = set()
        self._resident_lock = threading.Lock()
        self._resident_bytes = 0
        self._last_access = np.zeros(self.n_blocks, dtype=np.float64)
        # second-chance reference bits (eviction="clock"): set on every
        # acquisition, cleared by an eviction sweep — a block re-touched
        # between sweeps survives one round of pressure
        self._ref = np.zeros(self.n_blocks, dtype=bool)
        self._clock_hand = 0
        self._cond = threading.Condition()
        self.stats = PGFuseStats()
        self._stats_lock = threading.Lock()
        self._fs = fs
        # span tracer for storage reads: set directly, or inherited from
        # the owning mount (engines hand their tracer to PGFuseFS)
        self.tracer = None
        self._closed = False

    @property
    def resident_bytes(self) -> int:
        return self._resident_bytes

    # -- block acquisition (Fig. 1) ---------------------------------------
    def _read_underlying_range(self, b0: int, n_blocks: int) -> bytes:
        off = b0 * self.block_size
        n = min(n_blocks * self.block_size, self.size - off)
        data = self._pread_fn(self._fd, n, off)  # ONE large-granularity request
        with self._stats_lock:
            self.stats.underlying_reads += 1
            self.stats.underlying_bytes += len(data)
        return data

    def _read_with_retry(self, b0: int, n_blocks: int) -> bytes:
        """Bounded-retry wrapper over :meth:`_read_underlying_range`.

        The paper's Lustre deployments see *transient* OST errors (EIO
        that succeeds on the next attempt); with ``retries=r`` such an
        error is retried up to ``r`` times with a deterministic linear
        backoff (``retry_backoff_s * attempt``) before surfacing.  The
        retry sits ABOVE the underlying-read funnel so injected faults
        (tests/conftest.py::FaultyStorage wraps ``_read_underlying_range``)
        exercise the same policy a real storage error would.
        """
        tracer = self.tracer
        if tracer is None:
            tracer = (self._fs.tracer if self._fs is not None
                      else None) or _NULL_TRACER
        # tier=storage: under a request this nests inside the engine's
        # gather span; with no request context (producer threads) the
        # tracer suppresses it rather than recording an orphan root
        with tracer.span("pgfuse.read", tier="storage",
                         block=int(b0), blocks=int(n_blocks)) as sp:
            attempt = 0
            while True:
                try:
                    return self._read_underlying_range(b0, n_blocks)
                except OSError as e:
                    if attempt >= self.retries:
                        raise
                    attempt += 1
                    with self._stats_lock:
                        self.stats.retried_reads += 1
                    # one event per retry that goes back to storage:
                    # trace counts reconcile with stats.retried_reads
                    sp.event("retry", attempt=attempt,
                             errno=e.errno if e.errno is not None else -1)
                    time.sleep(self.retry_backoff_s * attempt)

    def _claim_readahead(self, b: int) -> list[int]:
        """Claim (-1 -> -2) a contiguous run [b, b+1, ...] for one load.

        Sequential readahead (paper §III read-ahead prefetchers): a miss on
        block ``b`` also claims up to ``readahead`` following NOT_LOADED
        blocks so the whole run is fetched with a single enlarged request —
        partition scans then issue ~1/(1+readahead) underlying calls.
        """
        claimed = [b]
        nxt = b + 1
        while (len(claimed) <= self.readahead and nxt < self.n_blocks
               and self._statuses.cas(nxt, NOT_LOADED, LOADING)):
            claimed.append(nxt)
            nxt += 1
        return claimed

    def acquire_block(self, b: int) -> bytes:
        """Pin block ``b`` for reading, loading it if necessary."""
        waited = False
        while True:
            if self._closed:
                raise ValueError("acquire on closed CachedFile")
            if self._statuses.add_reader(b):          # s >= 0 -> s+1
                data = self._blocks[b]
                assert data is not None
                self._ref[b] = True  # second chance: re-touched since sweep
                with self._stats_lock:
                    self.stats.cache_hits += 1
                    if waited:
                        self.stats.waits += 1
                return data
            if self._statuses.cas(b, NOT_LOADED, LOADING):  # -1 -> -2
                claimed = self._claim_readahead(b)
                if self._closed:
                    # close() raced our claim: it is now waiting for these
                    # LOADING blocks before os.close(fd), so revert the
                    # claims rather than pread a to-be-closed descriptor
                    for c in claimed:
                        ok = self._statuses.cas(c, LOADING, NOT_LOADED)
                        assert ok
                    with self._cond:
                        self._cond.notify_all()
                    raise ValueError("acquire on closed CachedFile")
                try:
                    run = self._read_with_retry(b, len(claimed))
                except BaseException:
                    for c in claimed:
                        ok = self._statuses.cas(c, LOADING, NOT_LOADED)
                        assert ok
                    with self._cond:
                        self._cond.notify_all()
                    raise
                expected_b = min(self.block_size, self.size - b * self.block_size)
                if len(run) < expected_b:
                    # A short underlying read that truncates the REQUESTED
                    # block must surface as an error: installing the stub
                    # would hand truncated bytes to every future reader,
                    # and pread() could spin forever on a zero-byte take.
                    # Claims revert (-2 -> -1) so a retry reloads cleanly.
                    for c in claimed:
                        ok = self._statuses.cas(c, LOADING, NOT_LOADED)
                        assert ok
                    with self._cond:
                        self._cond.notify_all()
                    raise IOError(
                        f"{self.path}: short read of block {b}: got "
                        f"{len(run)} of {expected_b} bytes")
                now = self._clock()
                installed_ahead = 0
                for j, c in enumerate(claimed):
                    expected = min(self.block_size, self.size - c * self.block_size)
                    chunk = run[j * self.block_size : j * self.block_size + expected]
                    if c != b and len(chunk) < expected:
                        # short underlying read: drop the readahead block
                        ok = self._statuses.cas(c, LOADING, NOT_LOADED)
                        assert ok
                        continue
                    self._blocks[c] = chunk
                    with self._resident_lock:
                        self._resident_set.add(c)
                        self._resident_bytes += len(chunk)
                    self._last_access[c] = now
                    # the requested block was demanded (ref set); readahead
                    # installs start cold — unconsumed prefetch is the
                    # first thing a clock sweep should reclaim
                    self._ref[c] = c == b
                    if self._fs is not None:
                        self._fs._resident_delta(len(chunk))
                    # loader becomes reader #1 of b; readahead blocks go idle
                    ok = self._statuses.cas(c, LOADING, 1 if c == b else LOADED)
                    assert ok, "nobody else may touch a LOADING block"
                    installed_ahead += c != b
                with self._stats_lock:
                    self.stats.cache_misses += 1
                    self.stats.readahead_blocks += installed_ahead
                    if waited:
                        self.stats.waits += 1
                with self._cond:
                    self._cond.notify_all()
                self._enforce_file_budget()
                self._enforce_share_budget()
                return self._blocks[b]
            # s is LOADING or REVOKING: wait for the owning thread
            waited = True
            with self._cond:
                s = self._statuses.load(b)
                if s in (LOADING, REVOKING):
                    self._cond.wait(timeout=0.05)

    def release_block(self, b: int) -> None:
        self._last_access[b] = self._clock()
        if self._statuses.release_reader(b) == 0:
            with self._cond:
                self._cond.notify_all()  # close() may be draining readers
        if self._fs is not None:
            self._fs._maybe_evict()

    def prefetch_range(self, offset: int, size: int) -> int:
        """Load every block overlapping [offset, offset+size), fetching
        each contiguous NOT_LOADED run with ONE enlarged request.

        The random-access primitive: a consumer that knows its request
        span up front (the query engine's merged packed-byte gathers)
        announces it here, so a cold multi-block span costs one storage
        request instead of one per block — the paper's enlarged-requests
        argument applied to request-shaped fetches rather than
        speculative readahead.  Returns the number of blocks loaded.
        Resident/loading blocks are skipped; short underlying reads drop
        the affected blocks silently (the eventual :meth:`pread` of a
        dropped block surfaces the error through the strict path).
        """
        if self._closed or size <= 0:
            return 0
        offset = max(0, offset)
        size = min(size, self.size - offset)
        if size <= 0:
            return 0
        # a span that cannot fit the budget would be installed and then
        # partially evicted before the consuming read arrives — strictly
        # worse (same bytes fetched twice) than letting pread() walk the
        # blocks itself, so decline and let the strict path handle it
        budget = self.max_resident_bytes
        if self._fs is not None and self._fs.max_resident_bytes is not None:
            budget = (self._fs.max_resident_bytes if budget is None
                      else min(budget, self._fs.max_resident_bytes))
        if budget is not None and size > budget:
            return 0
        b0 = offset // self.block_size
        b1 = (offset + size - 1) // self.block_size
        loaded = 0
        b = b0
        while b <= b1:
            if not self._statuses.cas(b, NOT_LOADED, LOADING):
                b += 1
                continue
            claimed = [b]
            nxt = b + 1
            while nxt <= b1 and self._statuses.cas(nxt, NOT_LOADED, LOADING):
                claimed.append(nxt)
                nxt += 1
            try:
                run = self._read_with_retry(b, len(claimed))
            except BaseException:
                for c in claimed:
                    ok = self._statuses.cas(c, LOADING, NOT_LOADED)
                    assert ok
                with self._cond:
                    self._cond.notify_all()
                raise
            now = self._clock()
            installed = 0
            for j, c in enumerate(claimed):
                expected = min(self.block_size, self.size - c * self.block_size)
                chunk = run[j * self.block_size : j * self.block_size + expected]
                if len(chunk) < expected:
                    ok = self._statuses.cas(c, LOADING, NOT_LOADED)
                    assert ok
                    continue
                self._blocks[c] = chunk
                with self._resident_lock:
                    self._resident_set.add(c)
                    self._resident_bytes += len(chunk)
                self._last_access[c] = now
                self._ref[c] = True  # the consumer announced it wants these
                if self._fs is not None:
                    self._fs._resident_delta(len(chunk))
                ok = self._statuses.cas(c, LOADING, LOADED)
                assert ok
                installed += 1
            with self._stats_lock:
                self.stats.span_fetch_blocks += installed
            with self._cond:
                self._cond.notify_all()
            loaded += installed
            b = nxt
        self._enforce_file_budget()
        self._enforce_share_budget()
        if self._fs is not None:
            self._fs._maybe_evict()
        return loaded

    # -- eviction (revocation by last-access time) -------------------------
    def try_revoke(self, b: int) -> int:
        """Attempt 0 -> -3 -> free -> -1.  Returns bytes freed (0 if busy)."""
        if not self._statuses.cas(b, LOADED, REVOKING):
            return 0
        data = self._blocks[b]
        self._blocks[b] = None
        freed = len(data) if data is not None else 0
        with self._resident_lock:
            self._resident_set.discard(b)
            self._resident_bytes -= freed
        self._ref[b] = False
        ok = self._statuses.cas(b, REVOKING, NOT_LOADED)
        assert ok
        with self._stats_lock:
            self.stats.evictions += 1
        with self._cond:
            self._cond.notify_all()
        return freed

    def sweep(self, need_bytes: int) -> int:
        """Revoke idle blocks until ``need_bytes`` are freed (or no more
        victims exist).  Victim order follows ``self.eviction``:

        * ``"lru"`` — strict last-access order (exact recency);
        * ``"clock"`` — second chance: the hand walks a snapshot of the
          resident blocks in index order from where it last stopped; a
          set reference bit buys the block one lap (the bit is cleared,
          the hand moves on), a clear bit makes it the victim.  Two laps
          bound the walk — after the first every survivor's bit is clear.

        Returns bytes actually freed.
        """
        freed = 0
        if self.eviction == EVICT_CLOCK:
            for _lap in range(2):
                if freed >= need_bytes:
                    break
                resident = self.resident_blocks()  # one snapshot per lap
                if resident.size == 0:
                    break
                start = int(np.searchsorted(resident, self._clock_hand))
                order = np.concatenate([resident[start:], resident[:start]])
                for b in order:
                    if freed >= need_bytes:
                        break
                    b = int(b)
                    self._clock_hand = b + 1
                    if self._ref[b]:
                        self._ref[b] = False  # second chance spent
                        continue
                    freed += self.try_revoke(b)
        else:
            order = sorted(self.resident_blocks(),
                           key=lambda b: self._last_access[b])
            for b in order:
                if freed >= need_bytes:
                    break
                freed += self.try_revoke(b)
        return freed

    def _enforce_file_budget(self) -> None:
        """Keep this FILE inside its own resident cap (when it has one).

        The per-file budget is what keeps a churning byte stream (packed
        neighbors under random queries, a feature store scan) from
        claiming the whole mount-wide budget and evicting another file's
        hot blocks: the churner reclaims from ITSELF first.
        """
        if self.max_resident_bytes is None:
            return
        over = self._resident_bytes - self.max_resident_bytes
        if over <= 0:
            return
        freed = self.sweep(over)
        if freed and self._fs is not None:
            self._fs._resident_delta(-freed)

    def _enforce_share_budget(self) -> None:
        """Keep this file's ENGINE share inside its cap (when in one)."""
        if self.share is not None:
            self.share.enforce()

    def resident_blocks(self) -> np.ndarray:
        with self._resident_lock:
            return np.array(sorted(self._resident_set), dtype=np.int64)

    # -- the consumer-facing read interface --------------------------------
    def pread(self, offset: int, size: int) -> bytes:
        """Positional read assembled from cached blocks."""
        if self._closed:
            raise ValueError("read on closed CachedFile")
        offset = max(0, offset)
        size = max(0, min(size, self.size - offset))
        if size == 0:
            return b""
        out = bytearray(size)
        pos = 0
        off = offset
        end = offset + size
        while off < end:
            b = off // self.block_size
            data = self.acquire_block(b)
            try:
                lo = off - b * self.block_size
                take = min(end - off, len(data) - lo)
                out[pos : pos + take] = data[lo : lo + take]
            finally:
                self.release_block(b)
            pos += take
            off += take
        with self._stats_lock:
            self.stats.bytes_served += size
        return bytes(out)

    def open(self) -> "CachedFileHandle":
        """A seekable file-like handle (one per consumer thread)."""
        return CachedFileHandle(self)

    def close(self, *, drain_timeout: float = 5.0) -> None:
        """Free every block through the Fig. 1 transitions (0 -> -3 -> -1).

        Pinned (s > 0) or in-flight (-2) blocks are *waited for*, not freed
        from under their readers — freeing a pinned block hands stale bytes
        to a thread that legally holds it.  Readers that never release
        within ``drain_timeout`` are treated as leaked and their blocks
        reclaimed as a last resort (with resident accounting kept exact).
        """
        if self._closed:
            return
        self._closed = True  # new acquisitions now fail fast
        deadline = time.monotonic() + drain_timeout
        pending = set(range(self.n_blocks))
        while pending:
            for b in list(pending):
                freed = self.try_revoke(b)  # 0 -> -3 -> free -> -1
                if freed and self._fs is not None:
                    self._fs._resident_delta(-freed)
                if self._statuses.load(b) == NOT_LOADED:
                    pending.discard(b)
            if not pending:
                break
            if time.monotonic() >= deadline:  # leaked readers: force-free
                freed = 0
                for b in pending:
                    data = self._blocks[b]
                    if data is not None:
                        freed += len(data)
                        self._blocks[b] = None
                        with self._resident_lock:
                            self._resident_set.discard(b)
                            self._resident_bytes -= len(data)
                if self._fs is not None and freed:
                    self._fs._resident_delta(-freed)
                break
            with self._cond:
                self._cond.wait(timeout=0.05)
        os.close(self._fd)


class CachedFileHandle:
    """Seek/read file-object adapter over a shared :class:`CachedFile`."""

    def __init__(self, cf: CachedFile):
        self._cf = cf
        self._pos = 0

    def seek(self, offset: int, whence: int = os.SEEK_SET) -> int:
        if whence == os.SEEK_SET:
            self._pos = offset
        elif whence == os.SEEK_CUR:
            self._pos += offset
        elif whence == os.SEEK_END:
            self._pos = self._cf.size + offset
        else:
            raise ValueError(f"bad whence {whence}")
        return self._pos

    def tell(self) -> int:
        return self._pos

    def read(self, size: int = -1) -> bytes:
        if size < 0:
            size = self._cf.size - self._pos
        data = self._cf.pread(self._pos, size)
        self._pos += len(data)
        return data

    def pread(self, offset: int, size: int) -> bytes:
        """Positional read — does NOT touch the seek cursor, so codec
        readers sharing one handle across threads need no lock."""
        return self._cf.pread(offset, size)

    def close(self) -> None:  # the underlying cache outlives handles
        pass

    def __enter__(self) -> "CachedFileHandle":
        return self

    def __exit__(self, *exc) -> None:
        pass


class EngineShare:
    """One serving engine's slice of a shared mount (multi-tenant budgets).

    A share groups the files one tenant (one serving model: its CompBin
    topology + feature/label column families) reads, and layers a budget
    over them ABOVE the per-file caps: the share's resident total is the
    sum of its member files', and when it exceeds ``max_resident_bytes``
    the share reclaims from its own members — biggest resident first,
    each member's own clock hand supplying the second chances — before
    the mount-wide sweep would ever look at another tenant.  Conversely
    :meth:`PGFuseFS._maybe_evict` protects every share still inside its
    budget, so the share is a reservation too: tenant A's churn cannot
    evict tenant B's warm set while B stays inside its slice.

    A file belongs to at most ONE share; genuinely shared files (two
    engines over one topology) stay unassigned and compete in the common
    pool.
    """

    def __init__(self, fs: "PGFuseFS", name: str,
                 max_resident_bytes: Optional[int]):
        self._fs = fs
        self.name = name
        self.max_resident_bytes = (None if max_resident_bytes is None
                                   else int(max_resident_bytes))
        self._files: Dict[str, CachedFile] = {}
        self._lock = threading.Lock()

    @property
    def resident_bytes(self) -> int:
        with self._lock:
            return sum(cf.resident_bytes for cf in self._files.values())

    def files(self) -> list:
        with self._lock:
            return list(self._files.values())

    def add_file(self, cf: CachedFile) -> None:
        if cf.share is not None and cf.share is not self:
            raise ValueError(
                f"{cf.path} already belongs to engine share "
                f"{cf.share.name!r}; a file joins at most one share "
                f"(shared files stay unassigned)")
        with self._lock:
            self._files[cf.path] = cf
        cf.share = self

    def mount(self, path: Union[str, os.PathLike], **mount_kwargs
              ) -> CachedFile:
        """Mount ``path`` on the underlying fs and claim it for this
        share (kwargs as :meth:`PGFuseFS.mount`)."""
        cf = self._fs.mount(path, **mount_kwargs)
        self.add_file(cf)
        return cf

    def within_budget(self) -> bool:
        return (self.max_resident_bytes is not None
                and self.resident_bytes <= self.max_resident_bytes)

    def enforce(self) -> int:
        """Reclaim from the share's OWN files until inside the budget.

        Victim order: biggest-resident member first (the churner pays
        first), each file's :meth:`CachedFile.sweep` supplying clock
        second chances.  Bounded: one pass over the members, each sweep
        capped at two laps, and a no-progress member is skipped — the
        call terminates even with every block pinned.  Returns bytes
        freed (mount-wide accounting kept exact).
        """
        if self.max_resident_bytes is None:
            return 0
        freed = 0
        for cf in sorted(self.files(), key=lambda f: -f.resident_bytes):
            over = self.resident_bytes - self.max_resident_bytes
            if over <= 0:
                break
            got = cf.sweep(over)
            if got and cf._fs is not None:
                cf._fs._resident_delta(-got)
            freed += got
        return freed


class PGFuseFS:
    """The "mount": a set of cached files under one shared memory budget.

    ``ParaGrapher`` mounts graph files here when the user passes
    ``use_pgfuse=True`` to :func:`repro.core.paragrapher.open_graph`, and
    unmounts (releasing all blocks) when the graph is closed — mirroring the
    paper's mount/unmount lifecycle.
    """

    def __init__(self, *, block_size: int = DEFAULT_BLOCK_SIZE,
                 max_resident_bytes: Optional[int] = None,
                 pread_fn=None,
                 readahead: int = 0,
                 eviction: str = EVICT_LRU,
                 file_budgets: Optional[Dict[str, int]] = None,
                 retries: int = 0,
                 retry_backoff_s: float = 0.005,
                 clock=None):
        if eviction not in EVICTION_POLICIES:
            raise ValueError(f"eviction must be one of {EVICTION_POLICIES}, "
                             f"got {eviction!r}")
        self.block_size = block_size
        self.max_resident_bytes = max_resident_bytes
        self.pread_fn = pread_fn
        self.readahead = int(readahead)
        self.eviction = eviction
        self.retries = int(retries)
        self.retry_backoff_s = float(retry_backoff_s)
        self.clock = clock
        # mount-wide span tracer (repro.obs): cached files inherit it
        # unless they carry their own; engines set it when constructed
        # with tracer= so storage reads nest under their gather spans
        self.tracer = None
        # per-file resident caps keyed by fspath; applied at mount() and
        # retroactively by set_file_budget()
        self._file_budgets = {os.fspath(k): int(v)
                              for k, v in (file_budgets or {}).items()}
        self._files: Dict[str, CachedFile] = {}
        self._shares: Dict[str, EngineShare] = {}
        # unmount refcounts for files several consumers mount and later
        # release independently (two tenants over one topology): see
        # retain()/unmount() — plain mount() calls do NOT count
        self._file_refs: Dict[str, int] = {}
        self._lock = threading.Lock()
        self._resident = 0

    def _resident_delta(self, d: int) -> None:
        with self._lock:
            self._resident += d

    @property
    def resident_bytes(self) -> int:
        return self._resident

    # -- multi-tenant engine shares ----------------------------------------
    #: "budget argument omitted" marker for register_engine — distinct
    #: from an explicit None, which means "uncap"
    _BUDGET_UNSET = object()

    def register_engine(self, name: str,
                        max_resident_bytes=_BUDGET_UNSET) -> EngineShare:
        """Create (or fetch) the named :class:`EngineShare`.

        Re-registering an existing name WITH a budget argument resizes
        it in place (and enforces the new cap immediately), so a serving
        fleet can resize tenants' slices at runtime; an explicit ``None``
        uncaps.  Omitting the argument fetches the share untouched — a
        fetch must never silently delete a tenant's cap/reservation.
        """
        with self._lock:
            share = self._shares.get(name)
            if share is None:
                budget = (None if max_resident_bytes is self._BUDGET_UNSET
                          else max_resident_bytes)
                share = EngineShare(self, name, budget)
                self._shares[name] = share
                return share
        if max_resident_bytes is self._BUDGET_UNSET:
            return share
        share.max_resident_bytes = (None if max_resident_bytes is None
                                    else int(max_resident_bytes))
        share.enforce()
        return share

    def engine_share(self, name: str) -> Optional[EngineShare]:
        with self._lock:
            return self._shares.get(name)

    def retain(self, path: Union[str, os.PathLike]) -> None:
        """Declare a long-lived co-owner of one mounted file.

        Each retain is paired with one later ``unmount(path)``, which
        only truly unmounts once every retainer released — so two
        GraphHandles over the SAME CompBin file on a shared mount can
        close independently without one dropping the other's warm
        cache.  Plain :meth:`mount` calls (used freely as accessors) do
        not count."""
        key = os.fspath(path)
        with self._lock:
            self._file_refs[key] = self._file_refs.get(key, 0) + 1

    def set_file_budget(self, path: Union[str, os.PathLike],
                        max_resident_bytes: Optional[int]) -> None:
        """Cap (or uncap, with None) one file's share of the cache.

        Applies to an already-mounted file immediately: an over-budget
        file sweeps itself down on its next install (and right here, so
        the cap holds even for a file that is never read again).
        """
        key = os.fspath(path)
        with self._lock:
            if max_resident_bytes is None:
                self._file_budgets.pop(key, None)
            else:
                self._file_budgets[key] = int(max_resident_bytes)
            cf = self._files.get(key)
        if cf is not None:
            cf.max_resident_bytes = max_resident_bytes
            cf._enforce_file_budget()

    def _maybe_evict(self) -> None:
        """Revoke idle blocks while over the mount-wide budget.

        Files holding no more than their OWN declared budget are
        protected in the first pass, and so are the member files of any
        ENGINE share still inside its share budget: per-file and
        per-engine budgets are reservations as well as caps, so another
        tenant's churn cannot evict a budgeted warm set while it stays
        inside its slice.  Only if the unprotected files cannot cover
        the overage (budgets that oversubscribe the mount) does a
        second pass consider everyone.
        Victim selection inside a pass honors ``self.eviction``: LRU
        takes a global strict last-access order; clock sweeps files
        biggest-resident first (the churner pays first), each file's own
        hand supplying the second chances.
        """
        if self.max_resident_bytes is None or self._resident <= self.max_resident_bytes:
            return
        with self._lock:
            files = list(self._files.values())

        def within_budget(cf: CachedFile) -> bool:
            if (cf.max_resident_bytes is not None
                    and cf.resident_bytes <= cf.max_resident_bytes):
                return True
            return cf.share is not None and cf.share.within_budget()

        for victims in ([cf for cf in files if not within_budget(cf)], files):
            if self._resident <= self.max_resident_bytes:
                return
            if self.eviction == EVICT_CLOCK:
                for cf in sorted(victims, key=lambda f: -f.resident_bytes):
                    over = self._resident - self.max_resident_bytes
                    if over <= 0:
                        return
                    freed = cf.sweep(over)
                    if freed:
                        self._resident_delta(-freed)
            else:
                candidates = []
                for cf in victims:
                    for b in cf.resident_blocks():
                        candidates.append((cf._last_access[b], cf, int(b)))
                candidates.sort(key=lambda t: t[0])
                for _, cf, b in candidates:
                    if self._resident <= self.max_resident_bytes:
                        return
                    freed = cf.try_revoke(b)
                    if freed:
                        self._resident_delta(-freed)

    def mount(self, path: Union[str, os.PathLike], *,
              max_resident_bytes: Optional[int] = None,
              readahead: Optional[int] = None,
              engine: Optional[Union[str, EngineShare]] = None) -> CachedFile:
        """Mount (or return the existing cache of) one file.

        ``max_resident_bytes`` sets the file's budget at first mount (and
        registers it for the mount's lifetime); ``readahead`` overrides
        the mount default for THIS file — a random-access consumer mounts
        its file with ``readahead=0`` next to a sequentially-streamed
        neighbor without splitting the memory budget.  ``engine`` claims
        the file for a registered :class:`EngineShare` (by object or
        name), layering that tenant's budget over the per-file one.
        """
        share = None
        if engine is not None:
            # resolve the share BEFORE opening anything: an unknown name
            # is an error (a typo must not silently strand the file in a
            # fresh uncapped share), and raising here must not leak a
            # freshly created CachedFile/fd
            if isinstance(engine, EngineShare):
                share = engine
            else:
                share = self.engine_share(engine)
                if share is None:
                    raise ValueError(
                        f"unknown engine share {engine!r}; call "
                        f"register_engine() first")
        key = os.fspath(path)
        with self._lock:
            if max_resident_bytes is not None:
                self._file_budgets[key] = int(max_resident_bytes)
            cf = self._files.get(key)
            created = cf is None
            if created:
                cf = CachedFile(
                    key, block_size=self.block_size, fs=self,
                    pread_fn=self.pread_fn,
                    readahead=self.readahead if readahead is None else readahead,
                    eviction=self.eviction,
                    max_resident_bytes=self._file_budgets.get(key),
                    retries=self.retries,
                    retry_backoff_s=self.retry_backoff_s,
                    clock=self.clock)
                self._files[key] = cf
        if not created:
            # already mounted: apply the overrides to the LIVE cache rather
            # than silently recording a budget that is never enforced
            if readahead is not None:
                cf.readahead = int(readahead)
            if max_resident_bytes is not None:
                cf.max_resident_bytes = int(max_resident_bytes)
                cf._enforce_file_budget()
        if share is not None:
            share.add_file(cf)
        return cf

    def open(self, path: Union[str, os.PathLike]) -> CachedFileHandle:
        return self.mount(path).open()

    def stats(self) -> PGFuseStats:
        agg = PGFuseStats()
        with self._lock:
            for cf in self._files.values():
                agg.merge(cf.stats)
        return agg

    def unmount(self, path: Optional[Union[str, os.PathLike]] = None) -> None:
        with self._lock:
            if path is None:
                files, self._files = list(self._files.values()), {}
                self._file_refs.clear()
            else:
                key = os.fspath(path)
                refs = self._file_refs.get(key, 0)
                if refs > 1:  # other retainers still hold this file
                    self._file_refs[key] = refs - 1
                    return
                self._file_refs.pop(key, None)
                cf = self._files.pop(key, None)
                files = [cf] if cf else []
        for cf in files:
            if cf.share is not None:
                with cf.share._lock:
                    cf.share._files.pop(cf.path, None)
                cf.share = None
            cf.close()

    def __enter__(self) -> "PGFuseFS":
        return self

    def __exit__(self, *exc) -> None:
        self.unmount()
