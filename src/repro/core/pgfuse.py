"""PG-Fuse — large-block caching file layer (paper §III).

The paper observes that the Java WebGraph reader issues frequent small
(<=128 kB) reads, under-utilizing high-bandwidth storage (SSD pools, Lustre)
and defeating read-ahead prefetchers.  PG-Fuse interposes a *filesystem in
user space* that (i) enlarges requested blocks (default **32 MiB**),
(ii) reduces the number of calls into the underlying filesystem, and
(iii) caches received blocks in memory for future calls.

Hardware adaptation (DESIGN.md §2): inside a managed TPU pod we cannot (and
need not) mount a kernel VFS layer, so the interposition point moves from
FUSE/VFS to the loader's file abstraction: :class:`CachedFile` implements
the same ``pread``/file interface every consumer in this framework uses
(CompBin reader, WebGraph reader, token-shard reader), which preserves the
paper's independence argument — the consumer is unmodified.

Block state machine (paper Fig. 1), one integer status per block, all
transitions via compare-and-swap:

      0   loaded and accessible (idle)
      >0  number of concurrent reader threads (counter)
     -1   not loaded
     -2   a thread is loading the block; others must wait
     -3   the block is being revoked (eviction by last-access time)

Transitions::

     -1 --cas--> -2 --load--> 1 --release--> 0 --acquire--> 1,2,3,...
      0 --cas--> -3 --free--> -1
"""

from __future__ import annotations

import dataclasses
import os
import threading
import time
from typing import BinaryIO, Dict, Optional, Union

import numpy as np

# Block states (paper Fig. 1)
LOADED = 0        # >= 0: reader count
NOT_LOADED = -1
LOADING = -2
REVOKING = -3

DEFAULT_BLOCK_SIZE = 32 * 2**20  # 32 MiB (paper §III)


@dataclasses.dataclass
class PGFuseStats:
    underlying_reads: int = 0      # calls into the underlying filesystem
    underlying_bytes: int = 0      # bytes fetched from it
    cache_hits: int = 0            # block acquisitions served from memory
    cache_misses: int = 0          # block acquisitions that triggered a load
    waits: int = 0                 # acquisitions that had to wait (-2/-3)
    evictions: int = 0             # blocks revoked
    bytes_served: int = 0          # bytes returned to consumers
    readahead_blocks: int = 0      # blocks loaded ahead of any request

    def merge(self, other: "PGFuseStats") -> None:
        for f in dataclasses.fields(self):
            setattr(self, f.name, getattr(self, f.name) + getattr(other, f.name))


class _StatusArray:
    """CAS-protected per-block status words.

    The paper uses C atomics; under the GIL we realize the identical
    transition diagram with striped mutexes guarding a numpy int64 array —
    every state change goes through :meth:`cas`, so the diagram of Fig. 1 is
    enforced verbatim (stress-tested in tests/test_pgfuse.py).
    """

    N_STRIPES = 64

    def __init__(self, n_blocks: int):
        self._status = np.full(n_blocks, NOT_LOADED, dtype=np.int64)
        self._locks = [threading.Lock() for _ in range(self.N_STRIPES)]

    def load(self, i: int) -> int:
        return int(self._status[i])

    def cas(self, i: int, expected: int, new: int) -> bool:
        with self._locks[i % self.N_STRIPES]:
            if self._status[i] == expected:
                self._status[i] = new
                return True
            return False

    def add_reader(self, i: int) -> bool:
        """Atomically increment a non-negative status (0->1, n->n+1)."""
        with self._locks[i % self.N_STRIPES]:
            s = int(self._status[i])
            if s >= 0:
                self._status[i] = s + 1
                return True
            return False

    def release_reader(self, i: int) -> int:
        with self._locks[i % self.N_STRIPES]:
            s = int(self._status[i])
            assert s >= 1, f"release on block {i} in state {s}"
            self._status[i] = s - 1
            return s - 1

    def snapshot(self) -> np.ndarray:
        return self._status.copy()


class CachedFile:
    """One file's block cache; shared by any number of reader handles."""

    def __init__(self, path: Union[str, os.PathLike], *,
                 block_size: int = DEFAULT_BLOCK_SIZE,
                 fs: Optional["PGFuseFS"] = None,
                 pread_fn=None,
                 readahead: int = 0):
        self.path = os.fspath(path)
        self.block_size = int(block_size)
        self.readahead = int(readahead)
        if self.block_size <= 0:
            raise ValueError("block_size must be positive")
        if self.readahead < 0:
            raise ValueError("readahead must be >= 0")
        self._fd = os.open(self.path, os.O_RDONLY)
        self.size = os.fstat(self._fd).st_size
        # injectable storage backend (benchmarks emulate Lustre/HDD
        # latency+bandwidth through here); default: the real filesystem
        self._pread_fn = pread_fn or (lambda fd, n, off: os.pread(fd, n, off))
        self.n_blocks = max(1, -(-self.size // self.block_size))
        self._statuses = _StatusArray(self.n_blocks)
        self._blocks: list[Optional[bytes]] = [None] * self.n_blocks
        # index of blocks with data installed, so eviction scans O(resident)
        # candidates instead of O(n_blocks) — release_block runs this on
        # every call when the cache sits at its budget (the streaming
        # loader's steady state)
        self._resident_set: set[int] = set()
        self._resident_lock = threading.Lock()
        self._last_access = np.zeros(self.n_blocks, dtype=np.float64)
        self._cond = threading.Condition()
        self.stats = PGFuseStats()
        self._stats_lock = threading.Lock()
        self._fs = fs
        self._closed = False

    # -- block acquisition (Fig. 1) ---------------------------------------
    def _read_underlying_range(self, b0: int, n_blocks: int) -> bytes:
        off = b0 * self.block_size
        n = min(n_blocks * self.block_size, self.size - off)
        data = self._pread_fn(self._fd, n, off)  # ONE large-granularity request
        with self._stats_lock:
            self.stats.underlying_reads += 1
            self.stats.underlying_bytes += len(data)
        return data

    def _claim_readahead(self, b: int) -> list[int]:
        """Claim (-1 -> -2) a contiguous run [b, b+1, ...] for one load.

        Sequential readahead (paper §III read-ahead prefetchers): a miss on
        block ``b`` also claims up to ``readahead`` following NOT_LOADED
        blocks so the whole run is fetched with a single enlarged request —
        partition scans then issue ~1/(1+readahead) underlying calls.
        """
        claimed = [b]
        nxt = b + 1
        while (len(claimed) <= self.readahead and nxt < self.n_blocks
               and self._statuses.cas(nxt, NOT_LOADED, LOADING)):
            claimed.append(nxt)
            nxt += 1
        return claimed

    def acquire_block(self, b: int) -> bytes:
        """Pin block ``b`` for reading, loading it if necessary."""
        waited = False
        while True:
            if self._closed:
                raise ValueError("acquire on closed CachedFile")
            if self._statuses.add_reader(b):          # s >= 0 -> s+1
                data = self._blocks[b]
                assert data is not None
                with self._stats_lock:
                    self.stats.cache_hits += 1
                    if waited:
                        self.stats.waits += 1
                return data
            if self._statuses.cas(b, NOT_LOADED, LOADING):  # -1 -> -2
                claimed = self._claim_readahead(b)
                if self._closed:
                    # close() raced our claim: it is now waiting for these
                    # LOADING blocks before os.close(fd), so revert the
                    # claims rather than pread a to-be-closed descriptor
                    for c in claimed:
                        ok = self._statuses.cas(c, LOADING, NOT_LOADED)
                        assert ok
                    with self._cond:
                        self._cond.notify_all()
                    raise ValueError("acquire on closed CachedFile")
                try:
                    run = self._read_underlying_range(b, len(claimed))
                except BaseException:
                    for c in claimed:
                        ok = self._statuses.cas(c, LOADING, NOT_LOADED)
                        assert ok
                    with self._cond:
                        self._cond.notify_all()
                    raise
                expected_b = min(self.block_size, self.size - b * self.block_size)
                if len(run) < expected_b:
                    # A short underlying read that truncates the REQUESTED
                    # block must surface as an error: installing the stub
                    # would hand truncated bytes to every future reader,
                    # and pread() could spin forever on a zero-byte take.
                    # Claims revert (-2 -> -1) so a retry reloads cleanly.
                    for c in claimed:
                        ok = self._statuses.cas(c, LOADING, NOT_LOADED)
                        assert ok
                    with self._cond:
                        self._cond.notify_all()
                    raise IOError(
                        f"{self.path}: short read of block {b}: got "
                        f"{len(run)} of {expected_b} bytes")
                now = time.monotonic()
                installed_ahead = 0
                for j, c in enumerate(claimed):
                    expected = min(self.block_size, self.size - c * self.block_size)
                    chunk = run[j * self.block_size : j * self.block_size + expected]
                    if c != b and len(chunk) < expected:
                        # short underlying read: drop the readahead block
                        ok = self._statuses.cas(c, LOADING, NOT_LOADED)
                        assert ok
                        continue
                    self._blocks[c] = chunk
                    with self._resident_lock:
                        self._resident_set.add(c)
                    self._last_access[c] = now
                    if self._fs is not None:
                        self._fs._resident_delta(len(chunk))
                    # loader becomes reader #1 of b; readahead blocks go idle
                    ok = self._statuses.cas(c, LOADING, 1 if c == b else LOADED)
                    assert ok, "nobody else may touch a LOADING block"
                    installed_ahead += c != b
                with self._stats_lock:
                    self.stats.cache_misses += 1
                    self.stats.readahead_blocks += installed_ahead
                    if waited:
                        self.stats.waits += 1
                with self._cond:
                    self._cond.notify_all()
                return self._blocks[b]
            # s is LOADING or REVOKING: wait for the owning thread
            waited = True
            with self._cond:
                s = self._statuses.load(b)
                if s in (LOADING, REVOKING):
                    self._cond.wait(timeout=0.05)

    def release_block(self, b: int) -> None:
        self._last_access[b] = time.monotonic()
        if self._statuses.release_reader(b) == 0:
            with self._cond:
                self._cond.notify_all()  # close() may be draining readers
        if self._fs is not None:
            self._fs._maybe_evict()

    # -- eviction (revocation by last-access time) -------------------------
    def try_revoke(self, b: int) -> int:
        """Attempt 0 -> -3 -> free -> -1.  Returns bytes freed (0 if busy)."""
        if not self._statuses.cas(b, LOADED, REVOKING):
            return 0
        data = self._blocks[b]
        self._blocks[b] = None
        with self._resident_lock:
            self._resident_set.discard(b)
        freed = len(data) if data is not None else 0
        ok = self._statuses.cas(b, REVOKING, NOT_LOADED)
        assert ok
        with self._stats_lock:
            self.stats.evictions += 1
        with self._cond:
            self._cond.notify_all()
        return freed

    def resident_blocks(self) -> np.ndarray:
        with self._resident_lock:
            return np.array(sorted(self._resident_set), dtype=np.int64)

    # -- the consumer-facing read interface --------------------------------
    def pread(self, offset: int, size: int) -> bytes:
        """Positional read assembled from cached blocks."""
        if self._closed:
            raise ValueError("read on closed CachedFile")
        offset = max(0, offset)
        size = max(0, min(size, self.size - offset))
        if size == 0:
            return b""
        out = bytearray(size)
        pos = 0
        off = offset
        end = offset + size
        while off < end:
            b = off // self.block_size
            data = self.acquire_block(b)
            try:
                lo = off - b * self.block_size
                take = min(end - off, len(data) - lo)
                out[pos : pos + take] = data[lo : lo + take]
            finally:
                self.release_block(b)
            pos += take
            off += take
        with self._stats_lock:
            self.stats.bytes_served += size
        return bytes(out)

    def open(self) -> "CachedFileHandle":
        """A seekable file-like handle (one per consumer thread)."""
        return CachedFileHandle(self)

    def close(self, *, drain_timeout: float = 5.0) -> None:
        """Free every block through the Fig. 1 transitions (0 -> -3 -> -1).

        Pinned (s > 0) or in-flight (-2) blocks are *waited for*, not freed
        from under their readers — freeing a pinned block hands stale bytes
        to a thread that legally holds it.  Readers that never release
        within ``drain_timeout`` are treated as leaked and their blocks
        reclaimed as a last resort (with resident accounting kept exact).
        """
        if self._closed:
            return
        self._closed = True  # new acquisitions now fail fast
        deadline = time.monotonic() + drain_timeout
        pending = set(range(self.n_blocks))
        while pending:
            for b in list(pending):
                freed = self.try_revoke(b)  # 0 -> -3 -> free -> -1
                if freed and self._fs is not None:
                    self._fs._resident_delta(-freed)
                if self._statuses.load(b) == NOT_LOADED:
                    pending.discard(b)
            if not pending:
                break
            if time.monotonic() >= deadline:  # leaked readers: force-free
                freed = 0
                for b in pending:
                    data = self._blocks[b]
                    if data is not None:
                        freed += len(data)
                        self._blocks[b] = None
                        with self._resident_lock:
                            self._resident_set.discard(b)
                if self._fs is not None and freed:
                    self._fs._resident_delta(-freed)
                break
            with self._cond:
                self._cond.wait(timeout=0.05)
        os.close(self._fd)


class CachedFileHandle:
    """Seek/read file-object adapter over a shared :class:`CachedFile`."""

    def __init__(self, cf: CachedFile):
        self._cf = cf
        self._pos = 0

    def seek(self, offset: int, whence: int = os.SEEK_SET) -> int:
        if whence == os.SEEK_SET:
            self._pos = offset
        elif whence == os.SEEK_CUR:
            self._pos += offset
        elif whence == os.SEEK_END:
            self._pos = self._cf.size + offset
        else:
            raise ValueError(f"bad whence {whence}")
        return self._pos

    def tell(self) -> int:
        return self._pos

    def read(self, size: int = -1) -> bytes:
        if size < 0:
            size = self._cf.size - self._pos
        data = self._cf.pread(self._pos, size)
        self._pos += len(data)
        return data

    def close(self) -> None:  # the underlying cache outlives handles
        pass

    def __enter__(self) -> "CachedFileHandle":
        return self

    def __exit__(self, *exc) -> None:
        pass


class PGFuseFS:
    """The "mount": a set of cached files under one shared memory budget.

    ``ParaGrapher`` mounts graph files here when the user passes
    ``use_pgfuse=True`` to :func:`repro.core.paragrapher.open_graph`, and
    unmounts (releasing all blocks) when the graph is closed — mirroring the
    paper's mount/unmount lifecycle.
    """

    def __init__(self, *, block_size: int = DEFAULT_BLOCK_SIZE,
                 max_resident_bytes: Optional[int] = None,
                 pread_fn=None,
                 readahead: int = 0):
        self.block_size = block_size
        self.max_resident_bytes = max_resident_bytes
        self.pread_fn = pread_fn
        self.readahead = int(readahead)
        self._files: Dict[str, CachedFile] = {}
        self._lock = threading.Lock()
        self._resident = 0

    def _resident_delta(self, d: int) -> None:
        with self._lock:
            self._resident += d

    @property
    def resident_bytes(self) -> int:
        return self._resident

    def _maybe_evict(self) -> None:
        """Revoke least-recently-used idle blocks while over budget."""
        if self.max_resident_bytes is None or self._resident <= self.max_resident_bytes:
            return
        # Gather (last_access, file, block) for all resident idle candidates.
        candidates = []
        with self._lock:
            files = list(self._files.values())
        for cf in files:
            for b in cf.resident_blocks():
                candidates.append((cf._last_access[b], cf, int(b)))
        candidates.sort(key=lambda t: t[0])
        for _, cf, b in candidates:
            if self._resident <= self.max_resident_bytes:
                break
            freed = cf.try_revoke(b)
            if freed:
                self._resident_delta(-freed)

    def mount(self, path: Union[str, os.PathLike]) -> CachedFile:
        key = os.fspath(path)
        with self._lock:
            cf = self._files.get(key)
            if cf is None:
                cf = CachedFile(key, block_size=self.block_size, fs=self,
                                pread_fn=self.pread_fn,
                                readahead=self.readahead)
                self._files[key] = cf
            return cf

    def open(self, path: Union[str, os.PathLike]) -> CachedFileHandle:
        return self.mount(path).open()

    def stats(self) -> PGFuseStats:
        agg = PGFuseStats()
        with self._lock:
            for cf in self._files.values():
                agg.merge(cf.stats)
        return agg

    def unmount(self, path: Optional[Union[str, os.PathLike]] = None) -> None:
        with self._lock:
            if path is None:
                files, self._files = list(self._files.values()), {}
            else:
                cf = self._files.pop(os.fspath(path), None)
                files = [cf] if cf else []
        for cf in files:
            cf.close()

    def __enter__(self) -> "PGFuseFS":
        return self

    def __exit__(self, *exc) -> None:
        self.unmount()
