"""CompBin — compact binary CSR representation (paper §IV).

CompBin stores the ``neighbors`` array of a CSR graph using the *minimum*
number of bytes per vertex ID: for a graph with ``|V|`` vertices it allocates

    b = ceil(log2(|V|) / 8)

bytes per ID (1..8).  Decoding a vertex ID is eq. (1) of the paper::

    id = sum_{i=0}^{b-1} neighbors[(offsets[v]+n)*b + i] << (8*i)

i.e. little-endian byte packing — a handful of shift+add operations, while
preserving O(1) random access into the neighbor list (byte address of the
n-th neighbor of v is ``(offsets[v]+n)*b``).  For ``2^24 <= |V| < 2^32`` the
format degenerates to plain 4-byte binary CSR.

On-disk layout (little-endian):

    +-------------------+----------------------------------------+
    | magic    4 bytes  | b"CBIN"                                |
    | version  u16      | 1                                      |
    | b        u8       | bytes per vertex ID                    |
    | flags    u8       | bit0: neighbors sorted per row         |
    | n_vertices u64    |                                        |
    | n_edges    u64    |                                        |
    +-------------------+----------------------------------------+
    | offsets  (|V|+1) * u64                                     |
    +------------------------------------------------------------+
    | neighbors |E| * b bytes (eq. (1) packing)                  |
    +------------------------------------------------------------+

The header is 24 bytes, so the offsets array begins at ``HEADER_SIZE`` and
the neighbors array at ``HEADER_SIZE + 8*(|V|+1)`` — both fixed, enabling
``mmap()``-style direct access exactly as the paper advertises for binary
CSR.
"""

from __future__ import annotations

import dataclasses
import io
import os
import struct
import threading
from typing import BinaryIO, Optional, Union

import numpy as np

from repro.core.csr import CSR

MAGIC = b"CBIN"
VERSION = 1
HEADER_SIZE = 24
FLAG_SORTED = 1

_HEADER_STRUCT = struct.Struct("<4sHBBQQ")
assert _HEADER_STRUCT.size == HEADER_SIZE

# Process-wide host-decode accounting.  The streaming loader's claim is that
# for CompBin inputs ZERO bytes are decoded on the host (eq. (1) runs in the
# Pallas kernel instead); this counter is how that claim is asserted.
_host_decode_lock = threading.Lock()
_host_decoded_bytes = 0


def host_decoded_bytes() -> int:
    """Total packed bytes decoded BY THE HOST (via :func:`decode_ids`)."""
    with _host_decode_lock:
        return _host_decoded_bytes


def reset_host_decoded_bytes() -> int:
    """Zero the counter; returns the previous value (tests/stats deltas)."""
    global _host_decoded_bytes
    with _host_decode_lock:
        prev, _host_decoded_bytes = _host_decoded_bytes, 0
        return prev


def bytes_per_vertex(n_vertices: int) -> int:
    """``b = ceil(log2(|V|)/8)`` (paper §IV). At least 1, at most 8.

    Computed with INTEGER bit arithmetic over the maximum representable
    id, ``|V| - 1``: the obvious ``math.ceil(math.log2(n) / 8)`` breaks
    at large ``|V|`` where the float rounds — e.g. ``log2(2**56 + 1)``
    rounds to exactly 56.0, yielding b=7 while the max id ``2**56``
    needs 8 bytes, so the encoder crashed on its own header's promise.
    ``(|V|-1).bit_length()`` is exact at every fence.
    """
    if n_vertices < 0:
        raise ValueError("n_vertices must be >= 0")
    return min(8, max(1, (max(n_vertices - 1, 1).bit_length() + 7) // 8))


def encode_ids(ids: np.ndarray, b: int) -> np.ndarray:
    """Pack vertex IDs into ``b`` little-endian bytes each.

    Returns a flat uint8 array of length ``len(ids) * b``.  Vectorized: the
    IDs are viewed as 8 little-endian bytes and the low ``b`` are kept.
    """
    if not 1 <= b <= 8:
        raise ValueError(f"b must be in [1,8], got {b}")
    ids = np.ascontiguousarray(ids, dtype=np.uint64)
    if ids.size and int(ids.max(initial=0)) >= (1 << (8 * b)) and b < 8:
        raise ValueError(f"vertex ID {int(ids.max())} does not fit in {b} bytes")
    # explicit little-endian view: a platform-endianness ``view(np.uint8)``
    # silently wrote byte-swapped ids on big-endian hosts (the wire format
    # is LE by definition — eq. (1) shifts low byte first)
    le = np.ascontiguousarray(ids, dtype="<u8")
    as_bytes = le.view(np.uint8).reshape(-1, 8)
    return np.ascontiguousarray(as_bytes[:, :b]).reshape(-1)


def decode_ids(packed: np.ndarray, b: int) -> np.ndarray:
    """Inverse of :func:`encode_ids` — eq. (1): ``sum(byte_i << 8i)``.

    Vectorized shift+add, mirroring the paper's decoder.  Output dtype is
    uint32 when ``b <= 4`` else uint64.
    """
    if not 1 <= b <= 8:
        raise ValueError(f"b must be in [1,8], got {b}")
    packed = np.ascontiguousarray(packed, dtype=np.uint8)
    if packed.size % b:
        raise ValueError(f"packed length {packed.size} not a multiple of b={b}")
    global _host_decoded_bytes
    with _host_decode_lock:
        _host_decoded_bytes += packed.size
    cols = packed.reshape(-1, b)
    out_dtype = np.uint32 if b <= 4 else np.uint64
    acc = np.zeros(cols.shape[0], dtype=out_dtype)
    for i in range(b):  # eq. (1): a few shifts and adds
        acc |= cols[:, i].astype(out_dtype) << out_dtype(8 * i)
    return acc


def compbin_nbytes(n_vertices: int, n_edges: int) -> int:
    """Total on-disk size of a CompBin file (header + offsets + packed IDs)."""
    b = bytes_per_vertex(n_vertices)
    return HEADER_SIZE + 8 * (n_vertices + 1) + b * n_edges


def write_compbin(path_or_file: Union[str, os.PathLike, BinaryIO], csr: CSR,
                  *, sorted_rows: bool = True) -> int:
    """Serialize ``csr`` to CompBin. Returns bytes written."""
    b = bytes_per_vertex(csr.n_vertices)
    header = _HEADER_STRUCT.pack(
        MAGIC, VERSION, b, FLAG_SORTED if sorted_rows else 0,
        csr.n_vertices, csr.n_edges,
    )
    packed = encode_ids(csr.neighbors.astype(np.uint64, copy=False), b)
    offs = csr.offsets.astype("<u8", copy=False)

    own = False
    if isinstance(path_or_file, (str, os.PathLike)):
        f: BinaryIO = open(path_or_file, "wb")
        own = True
    else:
        f = path_or_file
    try:
        n = f.write(header)
        n += f.write(offs.tobytes())
        n += f.write(packed.tobytes())
    finally:
        if own:
            f.close()
    return n


@dataclasses.dataclass
class CompBinHeader:
    b: int
    flags: int
    n_vertices: int
    n_edges: int

    @property
    def offsets_start(self) -> int:
        return HEADER_SIZE

    @property
    def neighbors_start(self) -> int:
        return HEADER_SIZE + 8 * (self.n_vertices + 1)

    @property
    def total_size(self) -> int:
        return self.neighbors_start + self.b * self.n_edges

    # -- the direct-addressing contract (core/codec.py) -------------------
    # These three methods are what makes a header consumable by the
    # random-access query engine without it knowing the codec: byte span
    # of a run of offsets, decode of that span, and the vertex gap that
    # corresponds to a byte merge gap.
    def offsets_span(self, a: int, z: int) -> tuple[int, int]:
        """(byte start, byte length) covering ``offsets[a ..= z+1]``."""
        return self.offsets_start + 8 * a, 8 * (z - a + 2)

    def decode_offsets(self, raw: bytes, a: int, z: int) -> np.ndarray:
        """int64 ``offsets[a ..= z+1]`` from an :meth:`offsets_span` read."""
        return np.frombuffer(raw, dtype="<u8",
                             count=z - a + 2).astype(np.int64)

    def offsets_gap_vertices(self, gap_bytes: int) -> int:
        """How many vertices a byte merge gap spans in the offsets array."""
        return max(1, gap_bytes // 8)


def _file_size(f) -> Optional[int]:
    """Best-effort size of a file-like object (None when undeterminable)."""
    size = getattr(f, "size", None)
    if isinstance(size, int):
        return size
    try:
        pos = f.tell()
        end = f.seek(0, os.SEEK_END)
        f.seek(pos)
        return int(end)
    except (OSError, ValueError, AttributeError):
        return None


def read_header(f) -> CompBinHeader:
    f.seek(0)
    raw = f.read(HEADER_SIZE)
    if len(raw) != HEADER_SIZE:
        raise ValueError("truncated CompBin header")
    magic, version, b, flags, n_v, n_e = _HEADER_STRUCT.unpack(raw)
    if magic != MAGIC:
        raise ValueError(f"bad magic {magic!r}; not a CompBin file")
    if version != VERSION:
        raise ValueError(f"unsupported CompBin version {version}")
    # A corrupt header must fail HERE with a clean error, not downstream
    # as a ZeroDivisionError (b=0) or a garbage decode (b>8, impossible
    # sizes): every field the direct-addressing arithmetic divides or
    # seeks by is validated before a single payload byte is trusted.
    hdr = CompBinHeader(b=b, flags=flags, n_vertices=n_v, n_edges=n_e)
    if not 1 <= b <= 8:
        raise IOError(f"corrupt CompBin header: b={b} outside [1, 8]")
    if flags & ~FLAG_SORTED:
        raise IOError(f"corrupt CompBin header: unknown flags 0x{flags:x}")
    actual = _file_size(f)
    if actual is not None and actual < hdr.total_size:
        raise IOError(
            f"corrupt/truncated CompBin file: header promises "
            f"{hdr.total_size} bytes (|V|={n_v}, |E|={n_e}, b={b}) but "
            f"the file holds {actual}")
    return hdr


class CompBinFile:
    """Random-access reader for a CompBin file (paper §IV).

    Works over any file-like object that supports ``seek``/``read`` — in
    particular a PG-Fuse :class:`~repro.core.pgfuse.CachedFile` — so the
    consumer is *unmodified* whether or not the cache is interposed (the
    same independence argument the paper makes for PG-Fuse vs. patching
    WebGraph).
    """

    def __init__(self, file: Union[str, os.PathLike, BinaryIO]):
        if isinstance(file, (str, os.PathLike)):
            self._f: BinaryIO = open(file, "rb")
            self._own = True
        else:
            self._f = file
            self._own = False
        # reads must be positional: the engine's executor calls
        # neighbors_of/read_edge_range concurrently, and an unlocked
        # seek+read pair interleaves (thread A seeks, thread B seeks,
        # thread A reads B's bytes).  Prefer the file's own pread (the
        # PG-Fuse handle has one); otherwise serialize seek+read.
        self._lock = threading.Lock()
        self._pread_fn = getattr(self._f, "pread", None)
        self.header = read_header(self._f)
        self._offsets_cache: Optional[np.ndarray] = None

    def _pread(self, start: int, nbytes: int) -> bytes:
        if self._pread_fn is not None:
            return self._pread_fn(start, nbytes)
        with self._lock:
            self._f.seek(start)
            return self._f.read(nbytes)

    # -- metadata ---------------------------------------------------------
    @property
    def n_vertices(self) -> int:
        return self.header.n_vertices

    @property
    def n_edges(self) -> int:
        return self.header.n_edges

    @property
    def b(self) -> int:
        return self.header.b

    # -- offsets ----------------------------------------------------------
    def offsets(self, v0: int = 0, v1: Optional[int] = None) -> np.ndarray:
        """Read offsets[v0 : v1+1] (inclusive upper fence)."""
        if v1 is None:
            v1 = self.n_vertices
        if self._offsets_cache is not None:
            return self._offsets_cache[v0 : v1 + 1]
        raw = self._pread(self.header.offsets_start + 8 * v0,
                          8 * (v1 - v0 + 1))
        return np.frombuffer(raw, dtype="<u8").astype(np.int64)

    def preload_offsets(self) -> None:
        self._offsets_cache = self.offsets(0, self.n_vertices)

    # -- neighbors --------------------------------------------------------
    def read_edge_range(self, e0: int, e1: int) -> np.ndarray:
        """Decode neighbors[e0:e1] (global edge indices) — eq. (1)."""
        b = self.header.b
        raw = self._pread(self.header.neighbors_start + b * e0, b * (e1 - e0))
        return decode_ids(np.frombuffer(raw, dtype=np.uint8), b)

    def neighbors_of(self, v: int) -> np.ndarray:
        """Direct random access to one adjacency list (the paper's key
        property vs. WebGraph: no sequential decode needed)."""
        offs = self.offsets(v, v + 1)
        return self.read_edge_range(int(offs[0]), int(offs[1]))

    def read_partition(self, v0: int, v1: int) -> tuple[np.ndarray, np.ndarray]:
        """Offsets (rebased to 0) and decoded neighbors for vertices [v0, v1)."""
        offs = self.offsets(v0, v1)
        nbrs = self.read_edge_range(int(offs[0]), int(offs[-1]))
        return (offs - offs[0]).astype(np.int64), nbrs

    def read_full(self) -> CSR:
        offs = self.offsets()
        nbrs = self.read_edge_range(0, self.n_edges)
        dtype = np.int32 if self.n_vertices <= np.iinfo(np.int32).max else np.int64
        return CSR(offsets=offs.astype(np.int64), neighbors=nbrs.astype(dtype))

    def raw_neighbor_bytes(self, e0: int, e1: int) -> np.ndarray:
        """Packed (undecoded) bytes for edges [e0, e1) — fed straight to the
        Pallas decode kernel so the (4-b)/4 bandwidth saving also applies to
        host->HBM and HBM->VMEM traffic (see kernels/compbin_decode)."""
        b = self.header.b
        raw = self._pread(self.header.neighbors_start + b * e0, b * (e1 - e0))
        return np.frombuffer(raw, dtype=np.uint8)

    def close(self) -> None:
        if self._own:
            self._f.close()

    def __enter__(self) -> "CompBinFile":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


def read_compbin(path: Union[str, os.PathLike, BinaryIO]) -> CSR:
    """Convenience: load a whole CompBin file into an in-memory CSR."""
    with CompBinFile(path) as f:
        return f.read_full()


def roundtrip_bytes(csr: CSR) -> bytes:
    """Serialize to bytes in memory (tests/benchmarks)."""
    buf = io.BytesIO()
    write_compbin(buf, csr)
    return buf.getvalue()
