"""Streaming partition->device graph loader (the paper's loading path,
carried all the way to the accelerator).

The paper accelerates storage->host loading (PG-Fuse enlarges+caches
reads, CompBin keeps decode a few shifts-and-adds); this module connects
that work to the JAX side so the *consumer* of the bandwidth is the
device, not host RAM:

    GraphHandle.partition_plan          edge-balanced vertex ranges
      -> read_async over PG-Fuse        producer pool, bounded buffers,
                                        sequential block readahead
      -> raw packed neighbor bytes      CompBin: NO host decode
      -> feature rows (stream_features) core.featstore through the SAME
                                        PG-Fuse mount, per vertex range
      -> double-buffered H2D transfer   PrefetchIterator staging thread
      -> on-device Pallas decode        kernels/compbin_decode, eq. (1)
      -> per-partition CSR shards (+x)  placed on the mesh "data" axis

For CompBin with b <= 4 the packed stream crosses the host->device link
undecoded, so the (4-b)/4 byte saving the paper claims for storage also
applies to H2D traffic — the same argument Log(Graph)/Zuckerli make for
compact representations: judge them by the bandwidth of the consumer
path.  WebGraph inputs (and CompBin with b > 4, whose IDs overflow int32
lanes) fall back to host decode; core/policy.py::choose_stream_decode is
the policy hook that picks the placement per graph.

Entry point::

    stream = stream_partitions(graph, mesh, n_buffers=2, readahead=2)
    for shard in stream:          # StreamedShard, device-resident
        ...
    print(stream.stats)           # per-stage: storage, H2D, decode

The iterator is bounded and backpressured end to end: at most
``readahead`` partitions sit decoded-or-packed on the host and at most
``n_buffers`` shards sit staged on device ahead of the consumer; a slow
consumer stalls the producers through the read_async buffer pool.
"""

from __future__ import annotations

import dataclasses
import queue
import threading
import time
from typing import Iterable, Iterator, Optional

import numpy as np

from repro.core import pgfuse, policy
from repro.core.csr import CSR
from repro.core.paragrapher import GraphHandle, PartitionBuffer


@dataclasses.dataclass
class StreamedShard:
    """One device-resident CSR partition (vertices [v0, v1))."""

    v0: int
    v1: int
    offsets: "jax.Array"      # int64[v1-v0+1], rebased to 0, replicated
    neighbors: "jax.Array"    # int32[n_edges] on the mesh "data" axis
    n_edges: int
    x: Optional["jax.Array"] = None  # float[v1-v0, d] feature rows, when a
                                     # feature store is streamed alongside
    y: Optional["jax.Array"] = None  # u8[v1-v0, 2] label family rows
                                     # ([class id, train-mask flag]), when a
                                     # label store is streamed alongside

    @property
    def n_vertices(self) -> int:
        return self.v1 - self.v0


#: StreamStats fields with dedicated merge rules (durations sum/max,
#: mode/reason strings tie-break); every OTHER field is a counter and
#: sums — derived from the dataclass so new counters merge automatically.
_MERGE_SPECIAL_FIELDS = ("decode_mode", "decode_reason", "decode_s", "wall_s")


@dataclasses.dataclass
class StreamStats:
    """Per-stage accounting for one stream (printed by benchmarks).

    In a multi-host load each process carries its own instance; per-host
    stats combine with :meth:`merge` (associative, so any reduction tree
    over the hosts yields the same totals).
    """

    partitions: int = 0
    vertices: int = 0
    edges: int = 0
    decode_mode: str = ""          # "device" | "host" ("mixed" after merge)
    decode_reason: str = ""
    # storage stage (PG-Fuse deltas; zero when the graph is not mounted)
    underlying_reads: int = 0
    underlying_bytes: int = 0
    cache_hits: int = 0
    cache_misses: int = 0
    readahead_blocks: int = 0
    # transfer stage
    bytes_h2d: int = 0             # topology bytes host->device (packed!)
    # decode stage
    host_decode_bytes: int = 0     # packed bytes decoded on host (0 = all
    decode_s: float = 0.0          # on-device, the CompBin fast path)
    # feature stage (stream_features; zero when no store is attached)
    feature_rows: int = 0          # feature rows streamed
    feature_bytes: int = 0         # bytes read from the feature store
    feature_bytes_h2d: int = 0     # feature bytes shipped host->device
    feature_read_s: float = 0.0    # time in feature-store reads
    feature_cache_hits: int = 0    # the store's own PG-Fuse block cache
    feature_cache_misses: int = 0
    # label stage (second column family; zero when no label store)
    label_rows: int = 0            # label/mask rows streamed
    label_bytes: int = 0           # bytes read from the label store
    wall_s: float = 0.0

    # Every derived rate guards against zero/negative durations: a stage
    # that never ran (empty plan slice on a host, sub-timer-resolution
    # decode) reports 0.0 instead of dividing by zero.
    @property
    def decode_edges_per_s(self) -> float:
        return self.edges / self.decode_s if self.decode_s > 0 else 0.0

    @property
    def h2d_bytes_per_s(self) -> float:
        return self.bytes_h2d / self.wall_s if self.wall_s > 0 else 0.0

    @property
    def edges_per_s(self) -> float:
        return self.edges / self.wall_s if self.wall_s > 0 else 0.0

    @property
    def feature_bytes_per_s(self) -> float:
        return self.feature_bytes / self.wall_s if self.wall_s > 0 else 0.0

    @property
    def feature_hit_rate(self) -> float:
        n = self.feature_cache_hits + self.feature_cache_misses
        return self.feature_cache_hits / n if n else 0.0

    def merge(self, other: "StreamStats") -> "StreamStats":
        """Combine two hosts' stats into the aggregate (returns a new
        instance).  Counters sum; decode seconds sum (total decode work);
        wall seconds take the max (hosts stream concurrently); mode/reason
        collapse to "mixed"/"" when the hosts disagree.
        """
        merged = {f.name: getattr(self, f.name) + getattr(other, f.name)
                  for f in dataclasses.fields(self)
                  if f.name not in _MERGE_SPECIAL_FIELDS}
        mode = (self.decode_mode if self.decode_mode == other.decode_mode
                else "mixed")
        reason = (self.decode_reason
                  if self.decode_reason == other.decode_reason else "")
        return StreamStats(decode_mode=mode, decode_reason=reason,
                           decode_s=self.decode_s + other.decode_s,
                           wall_s=max(self.wall_s, other.wall_s), **merged)

    def as_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["decode_edges_per_s"] = self.decode_edges_per_s
        d["h2d_bytes_per_s"] = self.h2d_bytes_per_s
        d["edges_per_s"] = self.edges_per_s
        d["feature_bytes_per_s"] = self.feature_bytes_per_s
        d["feature_hit_rate"] = self.feature_hit_rate
        return d


def merge_stats(stats: Iterable[StreamStats]) -> StreamStats:
    """Fold any number of per-host stats into one aggregate."""
    out = StreamStats()
    first = True
    for s in stats:
        out = dataclasses.replace(s) if first else out.merge(s)
        first = False
    return out


class GraphStream:
    """Bounded, backpressured iterator of device-resident CSR shards.

    Use :func:`stream_partitions` to construct.  Safe to abandon early:
    ``close()`` (also called by ``__exit__`` and on exhaustion) drops the
    in-flight partitions and unblocks the producer pool.
    """

    def __init__(self, graph: GraphHandle, mesh=None, *,
                 n_buffers: int = 2, readahead: int = 2,
                 n_parts: Optional[int] = None, n_workers: int = 2,
                 granule: Optional[int] = None,
                 decode_plan: Optional[policy.StreamDecodePlan] = None,
                 process_index: int = 0, process_count: int = 1,
                 feature_path=None, label_path=None, shares=None,
                 align: int = 1):
        # jax-facing imports are deferred to the staging stage so the
        # storage layer stays importable without jax
        from repro.kernels.compbin_decode import STREAM_GRANULE_IDS
        from repro.graph.partition import host_vertex_range, split_plan

        if not 0 <= process_index < process_count:
            raise ValueError(
                f"process_index {process_index} not in [0, {process_count})")
        self._graph = graph
        self._mesh = mesh
        self._granule = granule or STREAM_GRANULE_IDS
        self.process_index = process_index
        self.process_count = process_count
        # Every process derives the SAME global plan from the same file,
        # then streams only its split_plan slice — the cut points agree
        # across hosts with no communication (the plan, the capacity
        # ``shares``, and the block grid ``align`` are the same inputs on
        # every host; shares come from allgathered last-epoch stats, see
        # graph.partition.resplit_from_stats).
        self.global_plan = graph.partition_plan(
            self._default_parts(n_parts, mesh, process_count))
        self.plan = split_plan(self.global_plan, process_count,
                               shares=shares, align=align)[process_index]
        self.host_range = host_vertex_range(self.plan)
        self.decode_plan = decode_plan or policy.choose_stream_decode(
            graph.format, graph.bytes_per_id)
        self.stats = StreamStats(decode_mode=self.decode_plan.mode,
                                 decode_reason=self.decode_plan.reason)
        # stream_features stage: the node-feature store rides the same
        # PG-Fuse mount as the topology (shared memory budget + readahead
        # policy, its own per-file block cache and stats)
        self._features = None
        self._feat0 = pgfuse.PGFuseStats()
        if feature_path is not None:
            from repro.core import featstore
            self._features = featstore.open_featstore(feature_path,
                                                      fs=graph.fs)
            if self._features.n_rows != graph.n_vertices:
                self._features.close()
                raise ValueError(
                    f"feature store {feature_path} has "
                    f"{self._features.n_rows} rows for a graph of "
                    f"{graph.n_vertices} vertices")
            self._feat0 = self._features.pgfuse_stats() or pgfuse.PGFuseStats()
        # the label/mask column family rides the same mount the same way
        self._labels = None
        if label_path is not None:
            from repro.core import featstore
            self._labels = featstore.open_featstore(label_path, fs=graph.fs)
            if self._labels.n_rows != graph.n_vertices:
                self._labels.close()
                raise ValueError(
                    f"label store {label_path} has {self._labels.n_rows} "
                    f"rows for a graph of {graph.n_vertices} vertices")
        self._n_expected = len(self.plan)
        self._closed = False
        self._drop = threading.Event()   # tells the callback to discard
        self._t0 = time.perf_counter()
        # topology storage deltas come from the graph FILE's cache, not
        # the mount aggregate — a feature store on the same mount must
        # not leak its traffic into the topology counters
        self._pg0 = graph.pgfuse_file_stats() or pgfuse.PGFuseStats()

        # stage 1: storage + (for "host" mode) decode, on the producer pool
        self._rawq: "queue.Queue" = queue.Queue(maxsize=max(1, readahead))
        self._async = graph.read_async(
            self.plan, self._on_partition, n_buffers=max(2, n_buffers),
            n_workers=max(1, n_workers), raw=self.decode_plan.device)

        # stage 2: H2D staging + device decode, on a prefetch thread
        from repro.data.prefetch import PrefetchIterator
        self._prefetch: PrefetchIterator = PrefetchIterator(
            self._raw_iter(), depth=max(1, n_buffers), transform=self._stage)

    @staticmethod
    def _default_parts(n_parts: Optional[int], mesh,
                       process_count: int = 1) -> int:
        """GLOBAL partition count (an explicit ``n_parts`` is also global:
        it is the size of the shared plan the processes split)."""
        if n_parts is not None:
            return max(1, n_parts)
        total = 1
        if mesh is not None:
            for s in mesh.devices.shape:
                total *= s
        return policy.choose_stream_parts(total, process_count)

    # -- stage 1: the read_async consumer callback -------------------------
    def _on_partition(self, buf: PartitionBuffer) -> None:
        if self._drop.is_set():
            return
        if buf.error is not None:
            item = ("err", buf.error)
        elif buf.packed is not None:
            item = ("raw", (buf.v0, buf.v1, buf.offsets, buf.packed, buf.b))
        else:
            item = ("host", (buf.v0, buf.v1, buf.offsets, buf.neighbors))
        while not self._drop.is_set():
            try:
                self._rawq.put(item, timeout=0.05)
                return
            except queue.Full:
                continue

    def _raw_iter(self) -> Iterator:
        received = 0
        while received < self._n_expected:
            try:
                kind, payload = self._rawq.get(timeout=0.05)
            except queue.Empty:
                if self._drop.is_set():
                    return
                continue
            received += 1
            if kind == "err":
                raise payload
            yield (kind, payload)

    # -- stage 2: staging + decode ----------------------------------------
    def _stage(self, item) -> StreamedShard:
        import jax
        import jax.numpy as jnp

        from repro.distributed.sharding import stream_shard_placement
        from repro.kernels.compbin_decode import (compbin_decode,
                                                  pad_packed_for_stream)

        kind, payload = item
        t0 = time.perf_counter()
        place = lambda n_ids: stream_shard_placement(
            self._mesh, n_ids, process_index=self.process_index,
            process_count=self.process_count)
        if kind == "raw":
            v0, v1, offs, packed, b = payload
            padded, n = pad_packed_for_stream(packed, b, granule=self._granule)
            nbr_shard, off_shard = place(len(padded) // b)
            dev_packed = jnp.asarray(padded)          # H2D: packed bytes only
            if nbr_shard is not None:
                dev_packed = jax.device_put(dev_packed, nbr_shard)
            decoded = compbin_decode(dev_packed, b)   # eq. (1) on device
            neighbors = decoded[:n]
            h2d = padded.nbytes
        else:  # host-decoded partition (WebGraph, or CompBin with b > 4)
            v0, v1, offs, nbrs = payload
            n = len(nbrs)
            dtype = np.int32 if self._graph.n_vertices <= np.iinfo(np.int32).max \
                else np.int64
            host_nbrs = np.ascontiguousarray(nbrs, dtype=dtype)
            nbr_shard, off_shard = place(n)
            neighbors = jnp.asarray(host_nbrs)
            if nbr_shard is not None:
                neighbors = jax.device_put(neighbors, nbr_shard)
            h2d = host_nbrs.nbytes
            if self._graph.bytes_per_id > 0:
                # fixed-width packed bytes this partition decoded on the
                # host (any direct codec) — tallied per stream, NOT via
                # compbin's process-global counter, which concurrent
                # streams (multi-host simulator) share
                self.stats.host_decode_bytes += n * self._graph.bytes_per_id
            else:
                self.stats.host_decode_bytes += host_nbrs.nbytes
        offsets = jnp.asarray(offs)
        if off_shard is not None:
            offsets = jax.device_put(offsets, off_shard)
        neighbors.block_until_ready()   # charge decode to this stage, not
        offsets.block_until_ready()     # to the consumer's first use
        self.stats.decode_s += time.perf_counter() - t0
        self.stats.bytes_h2d += h2d + offs.nbytes
        # the feature stage runs OUTSIDE the decode timer: its cost is
        # feature_read_s, not decode_s
        x = self._stream_features(v0, v1, off_shard)
        y = self._stream_labels(v0, v1, off_shard)
        return StreamedShard(v0=v0, v1=v1, offsets=offsets,
                             neighbors=neighbors, n_edges=n, x=x, y=y)

    def _stream_features(self, v0: int, v1: int, placement):
        """The stream_features stage: feature rows [v0, v1) from the
        attached store — PG-Fuse enlarged/cached reads, same staging
        thread as topology H2D, so feature transfer double-buffers ahead
        of the consumer exactly like the packed neighbor bytes do.
        Feature rows are per-vertex (like offsets) and replicate on the
        host's submesh slice."""
        if self._features is None:
            return None
        import jax
        import jax.numpy as jnp

        t0 = time.perf_counter()
        rows = self._features.read_rows(v0, v1)
        self.stats.feature_read_s += time.perf_counter() - t0
        self.stats.feature_rows += rows.shape[0]
        self.stats.feature_bytes += rows.nbytes
        x = jnp.asarray(rows)
        if placement is not None:
            x = jax.device_put(x, placement)
        x.block_until_ready()
        self.stats.feature_bytes_h2d += rows.nbytes
        return x

    def _stream_labels(self, v0: int, v1: int, placement):
        """The second column family: label/mask rows [v0, v1) from the
        attached store — tiny next to features, but streaming them means
        full-graph batches carry ZERO synthetic tensors."""
        if self._labels is None:
            return None
        import jax
        import jax.numpy as jnp

        rows = self._labels.read_rows(v0, v1)
        self.stats.label_rows += rows.shape[0]
        self.stats.label_bytes += rows.nbytes
        y = jnp.asarray(rows)
        if placement is not None:
            y = jax.device_put(y, placement)
        y.block_until_ready()
        return y

    # -- the consumer-facing iterator --------------------------------------
    def __iter__(self) -> "GraphStream":
        return self

    def __next__(self) -> StreamedShard:
        try:
            shard = next(self._prefetch)
        except StopIteration:
            self._finalize()
            raise
        self.stats.partitions += 1
        self.stats.vertices += shard.n_vertices
        self.stats.edges += shard.n_edges
        return shard

    def _finalize(self) -> None:
        if self.stats.wall_s == 0.0:
            self.stats.wall_s = time.perf_counter() - self._t0
        pg = self._graph.pgfuse_file_stats()
        if pg is not None:
            self.stats.underlying_reads = pg.underlying_reads - self._pg0.underlying_reads
            self.stats.underlying_bytes = pg.underlying_bytes - self._pg0.underlying_bytes
            self.stats.cache_hits = pg.cache_hits - self._pg0.cache_hits
            self.stats.cache_misses = pg.cache_misses - self._pg0.cache_misses
            self.stats.readahead_blocks = pg.readahead_blocks - self._pg0.readahead_blocks
        if self._features is not None:
            fst = self._features.pgfuse_stats()
            if fst is not None:
                self.stats.feature_cache_hits = \
                    fst.cache_hits - self._feat0.cache_hits
                self.stats.feature_cache_misses = \
                    fst.cache_misses - self._feat0.cache_misses

    def close(self) -> None:
        if self._closed:
            return
        self._closed = True
        self._drop.set()
        self._prefetch.close()
        while True:  # unblock any producer stuck on a full raw queue
            try:
                self._rawq.get_nowait()
            except queue.Empty:
                break
        self._finalize()
        if self._features is not None:
            self._features.close()
        if self._labels is not None:
            self._labels.close()

    def __enter__(self) -> "GraphStream":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


def stream_partitions(graph: GraphHandle, mesh=None, *,
                      n_buffers: int = 2, readahead: int = 2,
                      n_parts: Optional[int] = None, n_workers: int = 2,
                      granule: Optional[int] = None,
                      decode_plan: Optional[policy.StreamDecodePlan] = None,
                      process_index: int = 0, process_count: int = 1,
                      feature_path=None, label_path=None, shares=None,
                      align: int = 1) -> GraphStream:
    """Stream an open graph to the device(s) partition by partition.

    Parameters mirror the pipeline's three bounds: ``readahead`` partitions
    may wait decoded/packed on the host, ``n_buffers`` shards may sit on
    device ahead of the consumer, and the PG-Fuse *block* readahead is set
    when the graph is opened (``open_graph(pgfuse_readahead=...)``).
    ``decode_plan`` overrides core.policy's CompBin-vs-WebGraph placement.

    ``feature_path`` attaches a :mod:`repro.core.featstore` node-feature
    store: each shard then carries its vertices' feature rows (``x``),
    read through the graph's PG-Fuse mount and double-buffered to device
    alongside the topology (the ``stream_features`` stage; per-stage
    bytes and cache hit rates land in :class:`StreamStats`).
    ``label_path`` attaches the label/mask column family the same way
    (``graph.features.labelstore_for_graph``): shards then carry ``y``
    ([class id, train-mask] u8 rows) and full-graph batches need no
    synthetic labels.

    Multi-host: every process opens the graph itself (its own PG-Fuse
    cache) and passes its ``process_index`` out of ``process_count``.  All
    processes compute the same global plan; each streams only its
    contiguous :func:`repro.graph.partition.split_plan` slice and places
    shards on its :func:`repro.distributed.sharding.host_submesh` slice of
    the mesh's "data" axis.  ``shares`` sizes the slices by measured host
    capacity (:func:`repro.graph.partition.resplit_from_stats`) and
    ``align`` snaps the inter-host cuts to a block grid so private caches
    never double-fetch a boundary feature block.  ``data/multihost.py``
    simulates this in one process for tests and single-node runs.
    """
    return GraphStream(graph, mesh, n_buffers=n_buffers, readahead=readahead,
                       n_parts=n_parts, n_workers=n_workers, granule=granule,
                       decode_plan=decode_plan, process_index=process_index,
                       process_count=process_count, feature_path=feature_path,
                       label_path=label_path, shares=shares, align=align)


def assemble_csr(shards: list[StreamedShard]) -> CSR:
    """Reassemble streamed shards into one host CSR (tests/verification).

    Shards may arrive out of order (read_async completes as storage does);
    they are keyed by their vertex range.
    """
    shards = sorted(shards, key=lambda s: s.v0)
    offsets = [np.zeros(1, dtype=np.int64)]
    neighbors = []
    base = 0
    for s in shards:
        offs = np.asarray(s.offsets, dtype=np.int64)
        offsets.append(offs[1:] + base)
        base += int(offs[-1])
        neighbors.append(np.asarray(s.neighbors))
    nbrs = (np.concatenate(neighbors) if neighbors
            else np.zeros(0, dtype=np.int32))
    return CSR(offsets=np.concatenate(offsets), neighbors=nbrs)
