"""Double-buffered background prefetch for host input pipelines.

Keeps ``depth`` batches in flight on a producer thread so host decode /
sampling overlaps device compute — on a pod this is the difference between
an input-bound and a compute-bound step when the storage path stalls.
"""

from __future__ import annotations

import queue
import threading
from typing import Callable, Iterable, Iterator, Optional, TypeVar

T = TypeVar("T")

_SENTINEL = object()


class PrefetchIterator(Iterator[T]):
    def __init__(self, it: Iterable[T], depth: int = 2,
                 transform: Optional[Callable[[T], T]] = None):
        self._q: "queue.Queue" = queue.Queue(maxsize=max(1, depth))
        self._transform = transform
        self._err: Optional[BaseException] = None
        self._thread = threading.Thread(
            target=self._run, args=(iter(it),), daemon=True, name="prefetch")
        self._thread.start()

    def _run(self, it: Iterator[T]) -> None:
        try:
            for item in it:
                if self._transform is not None:
                    item = self._transform(item)
                self._q.put(item)
        except BaseException as e:
            self._err = e
        finally:
            self._q.put(_SENTINEL)

    def __iter__(self) -> "PrefetchIterator[T]":
        return self

    def __next__(self) -> T:
        item = self._q.get()
        if item is _SENTINEL:
            if self._err is not None:
                raise self._err
            raise StopIteration
        return item
