"""Double-buffered background prefetch for host input pipelines.

Keeps ``depth`` batches in flight on a producer thread so host decode /
sampling overlaps device compute — on a pod this is the difference between
an input-bound and a compute-bound step when the storage path stalls.
"""

from __future__ import annotations

import queue
import threading
from typing import Callable, Iterable, Iterator, Optional, TypeVar

T = TypeVar("T")

_SENTINEL = object()


class PrefetchIterator(Iterator[T]):
    """Bounded producer-thread iterator; ``transform`` runs on the producer.

    ``close()`` stops the producer even if the consumer abandons the
    iterator mid-stream (the streaming graph loader closes its pipeline
    when a training job stops early); without it the producer would block
    forever on a full queue.
    """

    def __init__(self, it: Iterable[T], depth: int = 2,
                 transform: Optional[Callable[[T], T]] = None):
        self._q: "queue.Queue" = queue.Queue(maxsize=max(1, depth))
        self._transform = transform
        self._err: Optional[BaseException] = None
        self._stop = threading.Event()
        self._thread = threading.Thread(
            target=self._run, args=(iter(it),), daemon=True, name="prefetch")
        self._thread.start()

    def _put(self, item) -> bool:
        while not self._stop.is_set():
            try:
                self._q.put(item, timeout=0.05)
                return True
            except queue.Full:
                continue
        return False

    def _run(self, it: Iterator[T]) -> None:
        try:
            for item in it:
                if self._stop.is_set():
                    return
                if self._transform is not None:
                    item = self._transform(item)
                if not self._put(item):
                    return
        except BaseException as e:
            self._err = e
        finally:
            self._put(_SENTINEL)

    def __iter__(self) -> "PrefetchIterator[T]":
        return self

    def __next__(self) -> T:
        while True:  # timed get so a cross-thread close() can't strand us
            if self._stop.is_set():
                raise StopIteration
            try:
                item = self._q.get(timeout=0.05)
                break
            except queue.Empty:
                continue
        if item is _SENTINEL:
            if self._err is not None:
                raise self._err
            raise StopIteration
        return item

    def close(self) -> None:
        """Stop the producer and drop any queued items."""
        self._stop.set()
        while True:
            try:
                self._q.get_nowait()
            except queue.Empty:
                break
        self._thread.join(timeout=5.0)

    def __enter__(self) -> "PrefetchIterator[T]":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
