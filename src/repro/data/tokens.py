"""CompBin-packed token shards — the paper's idea applied to the LM input
pipeline (DESIGN.md §2, beyond-paper).

CompBin packs any bounded-alphabet integer stream in ``ceil(log2 A / 8)``
bytes per symbol.  Token IDs with vocab 151,936 (qwen2) or 49,152 (smollm)
need 3 bytes, not 4 — 25% less storage *and* 25% less host->device traffic
per step on every host of the pod.  Decode is eq. (1): shifts and adds,
either on host (numpy) or on device (kernels/compbin_decode).

Shard layout:

    magic b"CTOK" | version u16 | b u8 | pad u8 | vocab u64 | n_tokens u64
    packed tokens  n_tokens * b bytes (little-endian per token)

Shards are read through PG-Fuse (large-block cache) so many worker threads
issuing small batch reads against shared storage coalesce into 32 MiB
underlying requests — the exact pathology/remedy pair of paper §III.
"""

from __future__ import annotations

import os
import struct
from typing import BinaryIO, Iterator, Optional, Union

import numpy as np

from repro.core import compbin, pgfuse

MAGIC = b"CTOK"
VERSION = 1
HEADER_SIZE = 24
_HEADER_STRUCT = struct.Struct("<4sHBBQQ")
assert _HEADER_STRUCT.size == HEADER_SIZE


class TokenShardWriter:
    def __init__(self, path: Union[str, os.PathLike], vocab: int):
        self.path = os.fspath(path)
        self.vocab = int(vocab)
        self.b = compbin.bytes_per_vertex(self.vocab)
        self._f: BinaryIO = open(self.path, "wb")
        self._n = 0
        self._f.write(_HEADER_STRUCT.pack(MAGIC, VERSION, self.b, 0, self.vocab, 0))

    def append(self, tokens: np.ndarray) -> None:
        tokens = np.asarray(tokens).reshape(-1)
        if tokens.size and int(tokens.max()) >= self.vocab:
            raise ValueError("token id >= vocab")
        self._f.write(compbin.encode_ids(tokens.astype(np.uint64), self.b).tobytes())
        self._n += tokens.size

    def close(self) -> None:
        self._f.seek(0)
        self._f.write(_HEADER_STRUCT.pack(MAGIC, VERSION, self.b, 0, self.vocab, self._n))
        self._f.close()

    def __enter__(self) -> "TokenShardWriter":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


def write_token_shard(path: Union[str, os.PathLike], tokens: np.ndarray,
                      vocab: int) -> None:
    with TokenShardWriter(path, vocab) as w:
        w.append(tokens)


class TokenShardReader:
    """Random-access batch reader over a packed shard.

    ``use_pgfuse=True`` interposes the block cache; ``decode="device"``
    returns the *packed* uint8 batch for on-device decode with
    kernels/compbin_decode (saving (4-b)/4 of host->HBM traffic).
    """

    def __init__(self, path: Union[str, os.PathLike], *,
                 use_pgfuse: bool = False,
                 pgfuse_block_size: int = pgfuse.DEFAULT_BLOCK_SIZE,
                 pgfuse_max_resident_bytes: Optional[int] = None):
        self.path = os.fspath(path)
        self._fs: Optional[pgfuse.PGFuseFS] = None
        if use_pgfuse:
            self._fs = pgfuse.PGFuseFS(block_size=pgfuse_block_size,
                                       max_resident_bytes=pgfuse_max_resident_bytes)
        with self._open() as f:
            raw = f.read(HEADER_SIZE)
        magic, version, b, _, vocab, n_tokens = _HEADER_STRUCT.unpack(raw)
        if magic != MAGIC:
            raise ValueError(f"{path}: not a token shard (magic {magic!r})")
        if version != VERSION:
            raise ValueError(f"unsupported token shard version {version}")
        self.b, self.vocab, self.n_tokens = b, vocab, n_tokens

    def _open(self):
        if self._fs is not None:
            return self._fs.open(self.path)
        return open(self.path, "rb")

    def read_packed(self, start: int, count: int) -> np.ndarray:
        """Packed bytes for tokens [start, start+count) -> uint8[count*b]."""
        with self._open() as f:
            f.seek(HEADER_SIZE + start * self.b)
            raw = f.read(count * self.b)
        return np.frombuffer(raw, dtype=np.uint8)

    def read_tokens(self, start: int, count: int) -> np.ndarray:
        return compbin.decode_ids(self.read_packed(start, count), self.b).astype(np.int32)

    def batches(self, batch: int, seq: int, *, n_steps: Optional[int] = None,
                seed: int = 0, packed: bool = False) -> Iterator[np.ndarray]:
        """Yield [batch, seq(+1)] token windows (+1 for next-token labels).

        packed=True yields uint8[batch, (seq+1)*b] for on-device decode.
        """
        per = seq + 1
        n_windows = self.n_tokens // per
        if n_windows < batch:
            raise ValueError("shard too small for the requested batch")
        rng = np.random.default_rng(seed)
        step = 0
        while n_steps is None or step < n_steps:
            idx = rng.integers(0, n_windows, batch)
            rows = []
            for w in idx:
                if packed:
                    rows.append(self.read_packed(int(w) * per, per))
                else:
                    rows.append(self.read_tokens(int(w) * per, per))
            yield np.stack(rows)
            step += 1

    def pgfuse_stats(self) -> Optional[pgfuse.PGFuseStats]:
        return self._fs.stats() if self._fs is not None else None

    def close(self) -> None:
        if self._fs is not None:
            self._fs.unmount()
