"""Deterministic in-process multi-host simulator for the streaming loader.

Real multi-host JAX gives every process its own Python interpreter, its
own slice of the global device mesh, and (here) its own PG-Fuse mount of
the graph file.  The loader side of that topology is pure bookkeeping —
``process_index``/``process_count`` select a :func:`split_plan` slice of
the shared partition plan — so it can be exercised without
``jax.distributed``: this module runs N *simulated* processes inside one
interpreter, each with

  * its own :class:`~repro.core.paragrapher.GraphHandle` (and therefore
    its own PG-Fuse ``CachedFile`` + block cache + stats), and
  * its own :class:`~repro.data.graph_stream.GraphStream` carrying that
    process's ``process_index``, placing shards via ``host_submesh`` on a
    single CPU mesh.

The simulation is deterministic in everything tests assert on: the plan
slices, the shard contents, and the per-host counters are pure functions
of (file, n_parts, process_count) even though the hosts run concurrently.
Tier-1 uses it for end-to-end multi-host training tests; single-node
deployments use it to overlap N independent storage pipelines.
"""

from __future__ import annotations

import dataclasses
import threading
from typing import Optional

from repro.data.graph_stream import (GraphStream, StreamedShard, StreamStats,
                                     merge_stats, stream_partitions)


@dataclasses.dataclass
class HostResult:
    """One simulated process's view of a multi-host streamed load."""

    process_index: int
    shards: list
    stats: StreamStats
    host_range: tuple          # [v0, v1) vertex coverage of this host
    plan: list                 # this host's slice of the global plan
    n_vertices: int = 0        # |V| of the WHOLE graph (coverage checks)


def simulate_hosts(path, process_count: int, mesh=None, *,
                   open_kwargs: Optional[dict] = None,
                   concurrent: bool = True,
                   **stream_kwargs) -> list[HostResult]:
    """Stream ``path`` as ``process_count`` simulated hosts; return the
    per-host shards and stats ordered by process index.

    ``open_kwargs`` go to :func:`repro.core.paragrapher.open_graph` in
    every simulated process (default: PG-Fuse mounted, as a real host
    would); pass a callable ``open_kwargs(process_index) -> dict`` to give
    each host its own storage backend (benchmarks hand every host its own
    SimStorage clock this way).  ``stream_kwargs`` go to
    :func:`stream_partitions`; pass ``n_parts`` to pin the global plan
    when comparing runs with different host counts.  ``concurrent=False``
    runs the hosts back to back for debugging; the results are identical
    either way.
    """
    if process_count < 1:
        raise ValueError(f"process_count must be >= 1, got {process_count}")
    if callable(open_kwargs):
        kwargs_for = open_kwargs
    else:
        fixed = dict(open_kwargs) if open_kwargs else {"use_pgfuse": True}
        kwargs_for = lambda i: fixed
    results: list[Optional[HostResult]] = [None] * process_count
    errors: list[BaseException] = []
    err_lock = threading.Lock()

    def run_host(i: int) -> None:
        from repro.core import paragrapher

        try:
            with paragrapher.open_graph(path, **kwargs_for(i)) as g:
                with stream_partitions(g, mesh, process_index=i,
                                       process_count=process_count,
                                       **stream_kwargs) as stream:
                    shards = list(stream)
                results[i] = HostResult(
                    process_index=i, shards=shards, stats=stream.stats,
                    host_range=stream.host_range, plan=list(stream.plan),
                    n_vertices=g.n_vertices)
        except BaseException as e:
            with err_lock:
                errors.append(e)

    if concurrent and process_count > 1:
        threads = [threading.Thread(target=run_host, args=(i,),
                                    name=f"simhost-{i}", daemon=True)
                   for i in range(process_count)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
    else:
        for i in range(process_count):
            run_host(i)
    if errors:
        raise errors[0]
    return [r for r in results if r is not None]


def aggregate_stats(results: list[HostResult]) -> StreamStats:
    """Fold the per-host stats into cluster totals (associative merge)."""
    return merge_stats(r.stats for r in results)


def resplit_shares(results: list[HostResult], *, floor: float = 0.25):
    """Next-epoch capacity shares from a simulated epoch's results.

    The straggler-aware loop: ``simulate_hosts`` an epoch, feed the
    measured per-host wall times back through
    :func:`repro.graph.partition.stream_shares_from_stats`, and pass the
    returned shares to the next ``simulate_hosts(..., shares=...)`` — a
    host that loaded slowly gets a proportionally smaller slice.  On a
    real cluster the stats are allgathered and every process computes the
    same shares; the simulator has them all in hand already.
    """
    from repro.graph.partition import stream_shares_from_stats

    ordered = sorted(results, key=lambda r: r.process_index)
    return stream_shares_from_stats([r.stats for r in ordered], floor=floor)


def all_shards(results: list[HostResult]) -> list[StreamedShard]:
    """Every host's shards, ordered by vertex range — ready for
    :func:`repro.launch.data_gnn.streamed_graph_batch` /
    :func:`repro.data.graph_stream.assemble_csr`."""
    return sorted((s for r in results for s in r.shards), key=lambda s: s.v0)
