from repro.data.graph_stream import (GraphStream, StreamedShard,  # noqa: F401
                                     StreamStats, assemble_csr,
                                     stream_partitions)
from repro.data.prefetch import PrefetchIterator  # noqa: F401
from repro.data.tokens import (TokenShardReader, TokenShardWriter,  # noqa: F401
                               write_token_shard)
