from repro.data.graph_stream import (GraphStream, StreamedShard,  # noqa: F401
                                     StreamStats, assemble_csr, merge_stats,
                                     stream_partitions)
from repro.data.multihost import (HostResult, aggregate_stats,  # noqa: F401
                                  all_shards, resplit_shares, simulate_hosts)
from repro.data.prefetch import PrefetchIterator  # noqa: F401
from repro.data.tokens import (TokenShardReader, TokenShardWriter,  # noqa: F401
                               write_token_shard)
