from repro.data.prefetch import PrefetchIterator  # noqa: F401
from repro.data.tokens import (TokenShardReader, TokenShardWriter,  # noqa: F401
                               write_token_shard)
