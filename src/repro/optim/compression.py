"""Error-feedback quantized gradient all-reduce (distributed-optimization
trick for the data-parallel axis).

EF-SGD/1-bit-Adam lineage [Seide et al. 2014; arXiv:2102.02888]: each
device quantizes (grad + residual) to a few bits, the quantized values are
summed across the DP axis, and the quantization error is fed back into the
next step's residual — unbiased in the long run, wire traffic cut by
4x (int8 container) vs f32.

TPU/XLA adaptation (DESIGN.md §2): XLA exposes no sub-byte wire format, so
the smallest collective element is int8.  A psum accumulates *in* the wire
type, so the quantized levels must leave headroom for the axis size:
levels = 127 // axis_size (e.g. +/-7 for a 16-way data axis).  Cross-pod
reduction then runs hierarchically: int8 psum inside the pod, f32 psum of
the dequantized partial across the (2-way) pod axis — matching how
1-bit-Adam splits intra/inter-node phases.  Used inside ``shard_map`` train
steps (launch/train.py --compress-grads); the collective-bytes win is
visible in the dry-run HLO (all-reduce over s8 instead of f32).
"""

from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp


def ef_state_init(grads_like: Any) -> Any:
    """Residual (error-feedback) buffer, same structure as grads, f32."""
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads_like)


def _quantize(x: jnp.ndarray, levels: int, axis_name: str
              ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Symmetric per-tensor quantization with a *shared* scale (psum-max),
    so dequantization after the int8 psum is exact w.r.t. the shared grid."""
    amax = jax.lax.pmax(jnp.max(jnp.abs(x)), axis_name)
    scale = jnp.maximum(amax, 1e-12) / levels
    q = jnp.clip(jnp.round(x / scale), -levels, levels).astype(jnp.int8)
    return q, scale


def ef_compress_psum(grads: Any, ef: Any, axis_name: str, *,
                     axis_size: int,
                     outer_axis_name: Optional[str] = None) -> tuple[Any, Any]:
    """Quantized psum over ``axis_name`` with error feedback.

    Returns (mean_grads_f32, new_ef).  ``axis_size`` bounds the int8
    accumulation headroom; ``outer_axis_name`` (e.g. "pod") adds the
    hierarchical second-phase f32 psum.
    """
    levels = max(1, 127 // axis_size)

    def one(g, e):
        x = g.astype(jnp.float32) + e
        q, scale = _quantize(x, levels, axis_name)
        new_e = x - q.astype(jnp.float32) * scale     # local residual
        summed = jax.lax.psum(q, axis_name)           # s8 on the wire
        mean = summed.astype(jnp.float32) * scale / axis_size
        if outer_axis_name is not None:
            mean = jax.lax.pmean(mean, outer_axis_name)
        return mean, new_e

    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(ef)
    outs = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (treedef.unflatten([o[0] for o in outs]),
            treedef.unflatten([o[1] for o in outs]))
