"""AdamW with global-norm clipping, warmup+cosine schedule, and f32 master
weights for bf16 params (pure functional; no optax).

ZeRO-style sharding happens *outside* this module: the launcher passes
``out_shardings`` that place m/v/master on the data axis (see
distributed/sharding.py::zero_opt_specs), so each data shard owns 1/DP of
the optimizer state — the update is computed where the state lives and the
fresh params are all-gathered by GSPMD.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1
    master_f32: bool = True   # keep f32 master copies of low-precision params


def cosine_schedule(cfg: AdamWConfig, step: jnp.ndarray) -> jnp.ndarray:
    step = step.astype(jnp.float32)
    warm = cfg.lr * step / max(1, cfg.warmup_steps)
    t = jnp.clip((step - cfg.warmup_steps)
                 / max(1, cfg.total_steps - cfg.warmup_steps), 0.0, 1.0)
    cos = cfg.min_lr_frac * cfg.lr + (1 - cfg.min_lr_frac) * cfg.lr * 0.5 * (
        1 + jnp.cos(jnp.pi * t))
    return jnp.where(step < cfg.warmup_steps, warm, cos)


def global_norm(tree: Any) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def adamw_init(params: Any, cfg: AdamWConfig) -> dict:
    zeros32 = lambda p: jnp.zeros(p.shape, jnp.float32)
    state = {
        "step": jnp.zeros((), jnp.int32),
        "m": jax.tree.map(zeros32, params),
        "v": jax.tree.map(zeros32, params),
    }
    if cfg.master_f32:
        state["master"] = jax.tree.map(lambda p: p.astype(jnp.float32), params)
    return state


def adamw_update(params: Any, grads: Any, state: dict, cfg: AdamWConfig
                 ) -> tuple[Any, dict, dict]:
    """Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    lr = cosine_schedule(cfg, step)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    ref = state.get("master", params)

    def upd(p_ref, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mh = m / b1c
        vh = v / b2c
        p32 = p_ref.astype(jnp.float32)
        p32 = p32 - lr * (mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p32)
        return p32, m, v

    flat_ref, treedef = jax.tree.flatten(ref)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    out = [upd(*t) for t in zip(flat_ref, flat_g, flat_m, flat_v)]
    p32s = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])

    dtypes = jax.tree.map(lambda p: p.dtype, params)
    new_params = jax.tree.map(lambda p32, dt: p32.astype(dt), p32s, dtypes)
    new_state = {"step": step, "m": new_m, "v": new_v}
    if cfg.master_f32:
        new_state["master"] = p32s
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, new_state, metrics
