from repro.optim.adamw import (AdamWConfig, adamw_init, adamw_update,  # noqa: F401
                               cosine_schedule, global_norm)
from repro.optim.compression import (ef_compress_psum, ef_state_init)  # noqa: F401
