"""Pallas TPU kernel: CompBin vertex-ID decode (paper §IV, eq. (1)).

TPU rethink of the paper's CPU decoder (DESIGN.md §2): instead of decoding
on the host and shipping 4-byte IDs over PCIe/DMA, the *packed* b-byte
stream is DMA'd into HBM and unpacked to int32 in VMEM right before the
consuming gather — the (4-b)/4 bandwidth saving applies to every level of
the memory hierarchy, not just storage.

Tiling: the flat packed stream is viewed as ``(n, b)`` uint8 and blocked
``(block_n, b)`` into VMEM; each grid step widens the bytes on the 8x128
VPU lanes and reduces with ``b-1`` shift-adds — eq. (1) verbatim.  The
trailing (lane) dimension of the *output* tile is kept at a multiple of 128
by emitting ``(block_rows, 128)`` tiles; ops.py reshapes the flat stream
accordingly so the kernel sees hardware-aligned shapes.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _decode_kernel(packed_ref, out_ref, *, b: int):
    """packed_ref: uint8[rows, 128*b]  ->  out_ref: int32[rows, 128].

    Bytes are laid out little-endian per ID along the lane axis:
    packed[r, 128*i + l] is byte i of the ID in (r, l) — a *planar* layout
    chosen so each byte plane is a contiguous, lane-aligned (rows, 128)
    tile (an interleaved layout would need 8-bit lane shuffles, which the
    VPU does not do natively; ops.py performs the one-time transpose when
    staging the stream to the device).
    """
    acc = jnp.zeros(out_ref.shape, jnp.int32)
    for i in range(b):  # eq. (1): a few shifts and adds
        plane = packed_ref[:, 128 * i : 128 * (i + 1)].astype(jnp.int32)
        acc = acc | (plane << (8 * i))
    out_ref[...] = acc


@functools.partial(jax.jit, static_argnames=("b", "block_rows", "interpret"))
def compbin_decode_planar(planar: jnp.ndarray, *, b: int, block_rows: int = 256,
                          interpret: bool = True) -> jnp.ndarray:
    """Decode a planar-packed stream uint8[rows, 128*b] -> int32[rows, 128].

    ``rows`` must be a multiple of ``block_rows`` (ops.py pads).
    """
    rows = planar.shape[0]
    assert rows % block_rows == 0, (rows, block_rows)
    grid = (rows // block_rows,)
    return pl.pallas_call(
        functools.partial(_decode_kernel, b=b),
        grid=grid,
        in_specs=[pl.BlockSpec((block_rows, 128 * b), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((block_rows, 128), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, 128), jnp.int32),
        interpret=interpret,
    )(planar)
