from repro.kernels.compbin_decode.ops import compbin_decode  # noqa: F401
from repro.kernels.compbin_decode.ref import compbin_decode_ref  # noqa: F401
