from repro.kernels.compbin_decode.ops import (PACKED_STREAM_DECODERS,  # noqa: F401
                                              STREAM_GRANULE_IDS,
                                              compbin_decode,
                                              decode_packed_stream,
                                              packed_stream_decoder,
                                              pad_packed_for_stream)
from repro.kernels.compbin_decode.ref import compbin_decode_ref  # noqa: F401
