from repro.kernels.compbin_decode.ops import (STREAM_GRANULE_IDS,  # noqa: F401
                                              compbin_decode,
                                              decode_packed_stream,
                                              pad_packed_for_stream)
from repro.kernels.compbin_decode.ref import compbin_decode_ref  # noqa: F401
