"""Pure-jnp oracle for the CompBin decode kernel — eq. (1) of the paper."""

from __future__ import annotations

import jax.numpy as jnp


def compbin_decode_ref(packed: jnp.ndarray, b: int) -> jnp.ndarray:
    """Decode little-endian ``b``-byte packed vertex IDs.

    packed: uint8[n * b] (flat) or uint8[n, b].
    returns int32[n] (b <= 4 supported on-device; IDs must fit in int32,
    i.e. |V| < 2^31 — the dry-run checks this per architecture).
    """
    if not 1 <= b <= 4:
        raise ValueError(f"device decode supports b in [1,4], got {b}")
    cols = packed.reshape(-1, b).astype(jnp.int32)
    acc = jnp.zeros(cols.shape[0], jnp.int32)
    for i in range(b):  # eq. (1): sum(byte_i << 8i)
        acc = acc | (cols[:, i] << (8 * i))
    return acc
