"""jit'd public wrapper for the CompBin decode kernel."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.compbin_decode.kernel import compbin_decode_planar
from repro.kernels.compbin_decode.ref import compbin_decode_ref
from repro.kernels.utils import ceil_div, interpret_default


@functools.partial(jax.jit, static_argnames=("b", "n", "block_rows", "interpret"))
def _decode_impl(packed: jnp.ndarray, b: int, n: int, block_rows: int,
                 interpret: bool) -> jnp.ndarray:
    # Stage to the planar layout the kernel wants: (rows, 128*b) where
    # plane i holds byte i of each ID.  rows = padded_n / 128.
    lanes = 128
    rows = ceil_div(n, lanes)
    rows_p = ceil_div(rows, block_rows) * block_rows
    n_pad = rows_p * lanes
    flat = packed.reshape(-1)
    flat = jnp.pad(flat, (0, n_pad * b - flat.shape[0]))
    # (n_pad, b) -> (rows, lanes, b) -> (rows, b, lanes) -> (rows, b*lanes)
    planar = (
        flat.reshape(rows_p, lanes, b).transpose(0, 2, 1).reshape(rows_p, b * lanes)
    )
    out = compbin_decode_planar(planar, b=b, block_rows=block_rows,
                                interpret=interpret)
    return out.reshape(-1)[:n]


def compbin_decode(packed: jnp.ndarray, b: int, *, block_rows: int = 256,
                   interpret: bool | None = None,
                   use_kernel: bool = True) -> jnp.ndarray:
    """Decode CompBin-packed vertex IDs on device.

    packed: uint8[n*b] (or any shape with n*b elements, little-endian bytes
    per ID in memory order).  Returns int32[n].
    """
    if not 1 <= b <= 4:
        raise ValueError(f"b must be in [1,4] for device decode, got {b}")
    n = packed.size // b
    if not use_kernel:
        return compbin_decode_ref(packed.reshape(-1), b)
    if interpret is None:
        interpret = interpret_default()
    return _decode_impl(packed.reshape(-1), b, n, block_rows, interpret)
