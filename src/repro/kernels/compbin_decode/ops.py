"""jit'd public wrapper for the CompBin decode kernel."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.compbin_decode.kernel import compbin_decode_planar
from repro.kernels.compbin_decode.ref import compbin_decode_ref
from repro.kernels.utils import ceil_div, interpret_default

# Streaming granularity: partitions are padded (host-side, before the H2D
# copy) to a multiple of this many IDs so the decode jit-cache holds a few
# bucket shapes instead of one trace per partition, and every transfer in a
# double-buffered stream has one of a few fixed sizes.
STREAM_GRANULE_IDS = 1 << 15


def stream_bucket_ids(n: int, granule: int = STREAM_GRANULE_IDS) -> int:
    """Bucketed ID count for a partition of ``n`` IDs.

    Rounds up keeping 4 significant bits (quantum 2^(bits-4)), floored at
    1024: at most ~6% padding for any partition, O(16 log n) distinct
    shapes total so the decode jit-cache stays small.  ``granule`` caps the
    quantum so very large partitions stay aligned to a fixed multiple
    (uniform transfer sizes for the double buffers)."""
    if n <= 1024:
        return 1024
    q = min(1 << max(10, n.bit_length() - 4), granule)
    return ceil_div(n, q) * q


def pad_packed_for_stream(raw: np.ndarray, b: int, *,
                          granule: int = STREAM_GRANULE_IDS
                          ) -> tuple[np.ndarray, int]:
    """Zero-pad a packed uint8 stream up to a :func:`stream_bucket_ids`
    bucket.

    Returns (padded bytes, n_valid_ids).  The caller decodes the whole
    bucket on device and slices ``[:n_valid_ids]`` — padding decodes to
    vertex 0 and is dropped before anything consumes it.
    """
    raw = np.ascontiguousarray(raw, dtype=np.uint8)
    if raw.size % b:
        raise ValueError(f"packed length {raw.size} not a multiple of b={b}")
    n = raw.size // b
    n_pad = stream_bucket_ids(n, granule)
    if n_pad != n:
        raw = np.pad(raw, (0, (n_pad - n) * b))
    return raw, n


@functools.partial(jax.jit, static_argnames=("b", "n", "block_rows", "interpret"))
def _decode_impl(packed: jnp.ndarray, b: int, n: int, block_rows: int,
                 interpret: bool) -> jnp.ndarray:
    # Stage to the planar layout the kernel wants: (rows, 128*b) where
    # plane i holds byte i of each ID.  rows = padded_n / 128.
    lanes = 128
    rows = ceil_div(n, lanes)
    rows_p = ceil_div(rows, block_rows) * block_rows
    n_pad = rows_p * lanes
    flat = packed.reshape(-1)
    flat = jnp.pad(flat, (0, n_pad * b - flat.shape[0]))
    # (n_pad, b) -> (rows, lanes, b) -> (rows, b, lanes) -> (rows, b*lanes)
    planar = (
        flat.reshape(rows_p, lanes, b).transpose(0, 2, 1).reshape(rows_p, b * lanes)
    )
    out = compbin_decode_planar(planar, b=b, block_rows=block_rows,
                                interpret=interpret)
    return out.reshape(-1)[:n]


def decode_packed_stream(raw: np.ndarray, b: int, *,
                         block_rows: int = 256,
                         interpret: bool | None = None,
                         use_kernel: bool = True
                         ) -> tuple[np.ndarray, int]:
    """One-transfer device decode of a host-side packed byte stream.

    The serving path's building block: ``raw`` (uint8, ``n*b`` bytes —
    e.g. a micro-batch's merged packed-byte runs concatenated) is padded
    to a :func:`stream_bucket_ids` bucket, shipped with ONE explicit
    ``jax.device_put``, decoded by the Pallas kernel, and returned as
    int64 IDs on host, bit-identical to
    :func:`repro.core.compbin.decode_ids`.  Returns ``(ids, bytes_h2d)``
    where ``bytes_h2d`` is the padded transfer size (what actually
    crossed the link), so callers can account H2D traffic exactly.
    """
    raw = np.ascontiguousarray(raw, dtype=np.uint8).reshape(-1)
    if raw.size == 0:
        return np.zeros(0, dtype=np.int64), 0
    padded, n = pad_packed_for_stream(raw, b)
    dev = jax.device_put(padded)                # the batch's single H2D
    out = compbin_decode(dev, b, block_rows=block_rows,
                         interpret=interpret, use_kernel=use_kernel)
    return np.asarray(out[:n]).astype(np.int64), padded.size


def compbin_decode(packed: jnp.ndarray, b: int, *, block_rows: int = 256,
                   interpret: bool | None = None,
                   use_kernel: bool = True) -> jnp.ndarray:
    """Decode CompBin-packed vertex IDs on device.

    packed: uint8[n*b] (or any shape with n*b elements, little-endian bytes
    per ID in memory order).  Returns int32[n].

    b in [5,8] (graphs with |V| >= 2^32) is accepted for IDs that still fit
    int32 — the int32-lane ceiling every on-device consumer has anyway;
    the zero high bytes are stripped before the kernel so only 4 byte
    planes cross into VMEM.  IDs >= 2^31 must take the host decode path
    (core.policy.choose_stream_decode routes them there).
    """
    if not 1 <= b <= 8:
        raise ValueError(f"b must be in [1,8] for device decode, got {b}")
    n = packed.size // b
    if b > 4:
        packed = jnp.asarray(packed).reshape(n, b)
        try:
            has_high = bool((packed[:, 4:] != 0).any())
        except jax.errors.ConcretizationTypeError as e:
            raise ValueError(
                "b>4 device decode validates the high ID bytes and so needs "
                "a concrete (non-traced) input; decode b<=4 inside jit") from e
        if has_high:
            raise ValueError(
                f"b={b} packed stream holds IDs >= 2^32; they cannot decode "
                "to int32 lanes — use the host decode path "
                "(core.policy.choose_stream_decode routes this)")
        packed = packed[:, :4]
        b = 4
    if not use_kernel:
        return compbin_decode_ref(packed.reshape(-1), b)
    if interpret is None:
        interpret = interpret_default()
    return _decode_impl(packed.reshape(-1), b, n, block_rows, interpret)


#: codec name -> device stream decoder ``(raw_u8, b) -> (int64 ids,
#: bytes_h2d)``.  This is the op-surface registry the query engine and
#: streaming loader resolve through (repro.core.codec declares WHICH
#: codecs are direct; this maps each to its device decode).  LogCSR
#: byte-packs neighbors exactly like CompBin, so one kernel serves both;
#: a codec with a different packed layout registers its own entry.
PACKED_STREAM_DECODERS = {
    "compbin": decode_packed_stream,
    "logcsr": decode_packed_stream,
}


def packed_stream_decoder(codec_name: str):
    """The device stream decoder registered for ``codec_name``."""
    try:
        return PACKED_STREAM_DECODERS[codec_name]
    except KeyError:
        raise ValueError(
            f"no device stream decoder registered for codec "
            f"{codec_name!r}; registered: "
            f"{', '.join(sorted(PACKED_STREAM_DECODERS))}") from None
