"""Shared helpers for the Pallas TPU kernels."""

from __future__ import annotations

import os

import jax.numpy as jnp


def ceil_div(a: int, b: int) -> int:
    return -(-a // b)


def pad_to_multiple(x, multiple: int, axis: int, value=0):
    """Pad ``x`` along ``axis`` up to the next multiple of ``multiple``."""
    n = x.shape[axis]
    target = ceil_div(n, multiple) * multiple
    if target == n:
        return x
    pads = [(0, 0)] * x.ndim
    pads[axis] = (0, target - n)
    return jnp.pad(x, pads, constant_values=value)


def interpret_default() -> bool:
    """Kernels run in interpret mode unless a real TPU backend is present.

    The container is CPU-only; ``interpret=True`` executes the kernel body in
    Python for correctness validation, while the same ``pallas_call`` lowers
    to Mosaic on a real TPU.  Override with REPRO_PALLAS_INTERPRET=0/1.
    """
    env = os.environ.get("REPRO_PALLAS_INTERPRET")
    if env is not None:
        return env not in ("0", "false", "False")
    import jax

    return jax.default_backend() != "tpu"
