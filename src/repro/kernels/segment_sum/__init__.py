from repro.kernels.segment_sum.ops import segment_sum  # noqa: F401
from repro.kernels.segment_sum.ref import segment_sum_ref  # noqa: F401
