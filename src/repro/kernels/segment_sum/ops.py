"""jit'd public wrapper for the blocked segment-sum kernel."""

from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.segment_sum.kernel import segment_sum_blocked
from repro.kernels.segment_sum.ref import segment_sum_ref
from repro.kernels.utils import ceil_div, interpret_default, pad_to_multiple

# Above this the (N, block_d) output tile no longer fits VMEM comfortably;
# fall back to XLA's sorted scatter (jax.ops.segment_sum).
MAX_KERNEL_SEGMENTS = 8192


def segment_sum(messages: jnp.ndarray, segment_ids: jnp.ndarray,
                num_segments: int, *, block_e: int = 512, block_d: int = 128,
                interpret: bool | None = None,
                use_kernel: bool = True) -> jnp.ndarray:
    """Segment-sum messages[E, D] by segment_ids[E] into [num_segments, D].

    Padding edges may carry ``segment_ids == -1`` (dropped).  Kernel path is
    used for ``num_segments <= MAX_KERNEL_SEGMENTS``; otherwise XLA scatter.
    """
    if messages.ndim != 2 or segment_ids.ndim != 1:
        raise ValueError("messages must be [E, D], segment_ids [E]")
    if messages.shape[0] != segment_ids.shape[0]:
        raise ValueError("E mismatch between messages and segment_ids")
    if not use_kernel or num_segments > MAX_KERNEL_SEGMENTS:
        return segment_sum_ref(messages, segment_ids, num_segments)
    if interpret is None:
        interpret = interpret_default()
    E, D = messages.shape
    block_e = min(block_e, max(8, 1 << (E - 1).bit_length())) if E else block_e
    block_d = min(block_d, max(128, D))
    msgs = pad_to_multiple(messages.astype(jnp.float32), block_e, axis=0)
    msgs = pad_to_multiple(msgs, block_d, axis=1)
    ids = pad_to_multiple(segment_ids.astype(jnp.int32), block_e, axis=0, value=-1)
    out = segment_sum_blocked(
        msgs, ids, num_segments=num_segments, block_e=block_e,
        block_d=min(block_d, msgs.shape[1]), interpret=interpret,
    )
    return out[:, :D]
