"""Pure-jnp oracle for the blocked segment-sum kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def segment_sum_ref(messages: jnp.ndarray, segment_ids: jnp.ndarray,
                    num_segments: int) -> jnp.ndarray:
    """sum messages[e] into out[segment_ids[e]]; ids < 0 are dropped.

    messages: float[E, D]; segment_ids: int32[E]; returns float32[N, D].
    """
    valid = segment_ids >= 0
    ids = jnp.where(valid, segment_ids, 0)
    msgs = jnp.where(valid[:, None], messages, 0).astype(jnp.float32)
    return jax.ops.segment_sum(msgs, ids, num_segments=num_segments)
