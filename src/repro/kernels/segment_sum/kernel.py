"""Pallas TPU kernel: blocked segment-sum (GNN message aggregation).

The GNN SpMM regime (kernel taxonomy §GNN): messages laid out per edge,
reduced by destination vertex.  GPUs do this with atomics-based
scatter-add; the TPU has no HBM atomics, so the TPU-idiomatic realization
is a **one-hot matmul on the MXU**: a (block_e, N) one-hot of the segment
ids right-multiplied into the (block_e, block_d) message tile yields the
(N, block_d) partial sums.  The grid walks edge blocks in the minormost
dimension; because the TPU grid executes *sequentially*, the output tile
(N, block_d) can be revisited and accumulated in VMEM across edge blocks —
a reduction pattern with zero inter-step collectives.

FLOP overhead vs. a scatter: factor N/1 per message, but they are MXU FLOPs
at ~100x the VPU scatter throughput and the edge tile is read exactly once
from HBM — for N up to a few thousand (molecule/minibatch regimes) the
one-hot matmul wins.  ops.py falls back to ``jax.ops.segment_sum`` (XLA's
sorted-scatter) above ``max_kernel_segments``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _segsum_kernel(ids_ref, msg_ref, out_ref, *, num_segments: int):
    e_idx = pl.program_id(1)

    @pl.when(e_idx == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    ids = ids_ref[...]  # int32[block_e]
    msgs = msg_ref[...]  # f32[block_e, block_d]
    seg = jax.lax.broadcasted_iota(jnp.int32, (ids.shape[0], num_segments), 1)
    onehot = (ids[:, None] == seg).astype(msgs.dtype)  # [block_e, N]
    # (N, block_e) @ (block_e, block_d) on the MXU, f32 accumulation
    out_ref[...] += jax.lax.dot_general(
        onehot, msgs, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )


@functools.partial(jax.jit, static_argnames=("num_segments", "block_e",
                                             "block_d", "interpret"))
def segment_sum_blocked(messages: jnp.ndarray, segment_ids: jnp.ndarray,
                        *, num_segments: int, block_e: int = 512,
                        block_d: int = 128, interpret: bool = True) -> jnp.ndarray:
    """messages: f32[E, D] (E % block_e == 0, D % block_d == 0, padded by
    ops.py with segment_ids == -1 on padding); returns f32[N, D]."""
    E, D = messages.shape
    assert E % block_e == 0 and D % block_d == 0, (E, D, block_e, block_d)
    grid = (D // block_d, E // block_e)  # edge blocks minormost => sequential accum
    return pl.pallas_call(
        functools.partial(_segsum_kernel, num_segments=num_segments),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_e,), lambda d, e: (e,)),
            pl.BlockSpec((block_e, block_d), lambda d, e: (e, d)),
        ],
        out_specs=pl.BlockSpec((num_segments, block_d), lambda d, e: (0, d)),
        out_shape=jax.ShapeDtypeStruct((num_segments, D), jnp.float32),
        interpret=interpret,
    )(segment_ids, messages)
