"""Pallas TPU kernel: blocked causal GQA attention (FlashAttention regime).

IO-aware attention [FlashAttention, arXiv:2205.14135] adapted to the TPU
memory hierarchy: Q/K/V tiles are staged HBM->VMEM by BlockSpecs, scores
(block_q, block_k) live only in VMEM/VREGs, and the online-softmax running
state (m, l, acc) sits in VMEM scratch that persists across the
sequentially-executed KV grid dimension.  MXU dims: block_q/block_k are
multiples of 128 and Dh is 64/128 on all assigned archs.

GQA is folded into the index maps: query head h reads KV head
``h // (Hq // Hkv)`` — no repeat/materialization of K/V.

Grid: (B * Hq, Sq / block_q, Skv / block_k), KV minormost (sequential
accumulation).  Causal masking compares global q/k positions with the
decode convention (last query row attends to the whole KV prefix), so the
same kernel serves training (Sq == Skv) and chunked prefill (Sq < Skv).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  scale: float, causal: bool, block_q: int, block_k: int,
                  offset: int, kv_len: int):
    """``offset`` = unpadded (Skv - Sq): query row i attends to KV positions
    <= i + offset (decode convention).  ``kv_len`` = unpadded Skv, masking
    the KV padding columns for every query."""
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)  # [block_q, Dh]
    k = k_ref[0].astype(jnp.float32)  # [block_k, Dh]
    v = v_ref[0].astype(jnp.float32)  # [block_k, Dh]

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    kpos = ki * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 1)
    mask = kpos < kv_len
    if causal:
        qpos = qi * block_q + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 0) + offset
        mask &= kpos <= qpos
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]                      # [block_q, 1]
    m_cur = jnp.max(s, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)                   # [block_q, block_k]
    alpha = jnp.exp(m_prev - m_new)          # rescale of the old state
    l_ref[...] = alpha * l_ref[...] + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = alpha * acc_ref[...] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _finish():
        # rows with an empty receptive field (fully masked) produce l == 0
        l = l_ref[...]
        o_ref[0] = jnp.where(l > 0, acc_ref[...] / l, 0.0).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "causal", "scale", "block_q", "block_k", "offset", "kv_len", "interpret"))
def flash_attention_blocked(q, k, v, *, causal: bool, scale: float,
                            block_q: int, block_k: int, offset: int,
                            kv_len: int, interpret: bool = True):
    """q: [BHq, Sq, Dh]; k, v: [BHkv, Skv, Dh] with BHq = B*Hq flattened and
    the GQA group size inferred as BHq // BHkv.  Shapes pre-padded;
    ``offset``/``kv_len`` carry the unpadded alignment (see _flash_kernel)."""
    BHq, Sq, Dh = q.shape
    BHkv, Skv, _ = k.shape
    assert BHq % BHkv == 0
    group = BHq // BHkv
    assert Sq % block_q == 0 and Skv % block_k == 0
    grid = (BHq, Sq // block_q, Skv // block_k)
    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal,
        block_q=block_q, block_k=block_k, offset=offset, kv_len=kv_len)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, Dh), lambda h, qi, ki: (h, qi, 0)),
            pl.BlockSpec((1, block_k, Dh), lambda h, qi, ki: (h // group, ki, 0)),
            pl.BlockSpec((1, block_k, Dh), lambda h, qi, ki: (h // group, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, Dh), lambda h, qi, ki: (h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),    # m: running max
            pltpu.VMEM((block_q, 1), jnp.float32),    # l: running denom
            pltpu.VMEM((block_q, Dh), jnp.float32),   # acc: running numerator
        ],
        interpret=interpret,
    )(q, k, v)
