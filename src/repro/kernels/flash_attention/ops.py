"""jit'd public wrapper for the blocked GQA flash-attention kernel."""

from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_blocked
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.utils import interpret_default, pad_to_multiple


def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = True, scale: float | None = None,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool | None = None,
                    use_kernel: bool = True) -> jnp.ndarray:
    """Blocked causal GQA attention.

    q: [B, Hq, Sq, Dh]; k, v: [B, Hkv, Skv, Dh].  Returns [B, Hq, Sq, Dh]
    with q's dtype.  Sequences are padded to the block size internally; the
    causal mask uses the decode convention (last query sees the full KV).
    """
    B, Hq, Sq, Dh = q.shape
    _, Hkv, Skv, _ = k.shape
    if scale is None:
        scale = Dh ** -0.5
    if not use_kernel:
        return attention_ref(q, k, v, causal=causal, scale=scale).astype(q.dtype)
    if interpret is None:
        interpret = interpret_default()

    bq = min(block_q, max(8, Sq))
    bk = min(block_k, max(8, Skv))
    qp = pad_to_multiple(q.reshape(B * Hq, Sq, Dh), bq, axis=1)
    kp = pad_to_multiple(k.reshape(B * Hkv, Skv, Dh), bk, axis=1)
    vp = pad_to_multiple(v.reshape(B * Hkv, Skv, Dh), bk, axis=1)
    sq_p = qp.shape[1]
    # The kernel masks KV padding columns (kpos >= Skv) for every query and
    # aligns causality with the unpadded offset Skv - Sq.
    out = flash_attention_blocked(
        qp, kp, vp, causal=causal, scale=scale, block_q=bq, block_k=bk,
        offset=Skv - Sq, kv_len=Skv, interpret=interpret)
    out = out.reshape(B, Hq, sq_p, Dh)[:, :, :Sq]
    return out.astype(q.dtype)
