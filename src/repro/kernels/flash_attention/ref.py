"""Pure-jnp oracle: dense (materialized-scores) GQA attention."""

from __future__ import annotations

import jax.numpy as jnp


def attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                  causal: bool = True, scale: float | None = None) -> jnp.ndarray:
    """q: [B, Hq, Sq, Dh]; k, v: [B, Hkv, Skv, Dh]; Hq % Hkv == 0.

    Returns [B, Hq, Sq, Dh] in float32.
    """
    B, Hq, Sq, Dh = q.shape
    _, Hkv, Skv, _ = k.shape
    assert Hq % Hkv == 0, (Hq, Hkv)
    group = Hq // Hkv
    if scale is None:
        scale = Dh ** -0.5
    kk = jnp.repeat(k, group, axis=1)
    vv = jnp.repeat(v, group, axis=1)
    scores = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                        kk.astype(jnp.float32)) * scale
    if causal:
        # decode convention: the last query attends to the full KV
        qpos = jnp.arange(Sq)[:, None] + (Skv - Sq)
        kpos = jnp.arange(Skv)[None, :]
        scores = jnp.where(kpos <= qpos, scores, -jnp.inf)
    probs = jnp.exp(scores - scores.max(-1, keepdims=True))
    probs = probs / probs.sum(-1, keepdims=True)
    return jnp.einsum("bhqk,bhkd->bhqd", probs, vv.astype(jnp.float32))
