"""Fault tolerance + straggler mitigation for long-running pod jobs.

* :class:`ResilientTrainer` — wraps the train loop: periodic (async)
  checkpoints, automatic restore-from-latest on step failure (a preempted
  or crashed host surfaces as an exception on relaunch), bounded retries.
  The same checkpoint set serves *elastic* restarts on a different device
  count (checkpoint stores global arrays; restore re-shards).
* :class:`StragglerMonitor` — per-host step-time tracking with a robust
  (median * k) threshold, mirroring production heartbeat monitors.  On a
  real pod each host reports its step wall-time through the coordinator;
  the detection logic is host-count agnostic and unit-tested with
  synthetic fleets (tests/test_fault_tolerance.py).
"""

from __future__ import annotations

import collections
import logging
import time
from typing import Any, Callable, Optional

import numpy as np

from repro import checkpoint as ckpt

log = logging.getLogger(__name__)


class StragglerMonitor:
    """Flags hosts whose recent step times exceed ``k x`` the fleet median."""

    def __init__(self, n_hosts: int, *, window: int = 20, k: float = 2.0,
                 min_samples: int = 5):
        self.n_hosts = n_hosts
        self.k = k
        self.min_samples = min_samples
        self._times = [collections.deque(maxlen=window) for _ in range(n_hosts)]

    def record(self, host: int, step_time: float) -> None:
        self._times[host].append(step_time)

    def record_step(self, times: "np.ndarray | list[float]") -> None:
        for h, t in enumerate(times):
            self.record(h, float(t))

    def stragglers(self) -> list[int]:
        medians = []
        for dq in self._times:
            if len(dq) < self.min_samples:
                return []  # not enough evidence fleet-wide yet
            medians.append(float(np.median(dq)))
        fleet = float(np.median(medians))
        return [h for h, m in enumerate(medians) if m > self.k * fleet]

    def should_evict(self, host: int, *, patience: int = 3) -> bool:
        """Sustained straggler: the last ``patience`` samples all exceed."""
        dq = self._times[host]
        if len(dq) < max(patience, self.min_samples):
            return False
        fleet = float(np.median([np.median(d) for d in self._times if len(d)]))
        recent = list(dq)[-patience:]
        return all(t > self.k * fleet for t in recent)


class ResilientTrainer:
    """Checkpointed, restart-safe training loop driver.

    ``step_fn(state, batch) -> (state, metrics)`` must be a pure jitted
    function; ``state`` is any pytree (params + optimizer state + step).
    """

    def __init__(self, step_fn: Callable, state: Any, *, ckpt_dir: str,
                 ckpt_every: int = 50, keep_last: int = 3,
                 max_retries: int = 3,
                 restore_shardings: Any = None):
        self.step_fn = step_fn
        self.ckpt_dir = ckpt_dir
        self.ckpt_every = ckpt_every
        self.max_retries = max_retries
        self._checkpointer = ckpt.AsyncCheckpointer(ckpt_dir, keep_last=keep_last)
        # restart-from-latest on construction (relaunch after a crash)
        step, state = ckpt.restore_latest(ckpt_dir, state,
                                          shardings=restore_shardings)
        self.state = state
        self.start_step = step or 0
        if step is not None:
            log.info("restored checkpoint at step %d", step)

    def run(self, batches, *, n_steps: int,
            on_metrics: Optional[Callable[[int, Any], None]] = None,
            inject_failure_at: Optional[int] = None) -> Any:
        """Run ``n_steps`` training steps; retries a failing step from the
        last checkpoint.  ``inject_failure_at`` raises once at that step
        (used by the integration tests to prove the recovery path)."""
        it = iter(batches)
        step = self.start_step
        retries = 0
        injected = False
        while step < n_steps:
            batch = next(it)
            try:
                if inject_failure_at == step and not injected:
                    injected = True
                    raise RuntimeError(f"injected host failure at step {step}")
                t0 = time.monotonic()
                self.state, metrics = self.step_fn(self.state, batch)
                dt = time.monotonic() - t0
                retries = 0
            except Exception as e:  # noqa: BLE001 — any step failure
                retries += 1
                if retries > self.max_retries:
                    raise
                log.warning("step %d failed (%s); restoring latest checkpoint "
                            "(retry %d/%d)", step, e, retries, self.max_retries)
                self._checkpointer.wait()
                restored, self.state = ckpt.restore_latest(self.ckpt_dir, self.state)
                step = restored or 0
                continue
            step += 1
            if on_metrics is not None:
                on_metrics(step, {**metrics, "step_time_s": dt})
            if step % self.ckpt_every == 0 or step == n_steps:
                self._checkpointer.save(step, self.state)
        self._checkpointer.wait()
        return self.state
