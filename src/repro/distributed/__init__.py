from repro.distributed.fault_tolerance import (ResilientTrainer,  # noqa: F401
                                               StragglerMonitor)
from repro.distributed.sharding import (batch_axes, din_specs,  # noqa: F401
                                        gnn_specs, lm_param_specs,
                                        zero_opt_specs)
