"""Elastic re-meshing: move a checkpointed state onto a different mesh.

Checkpoints store *global* arrays (checkpoint/checkpointer.py), so elastic
scaling is a restore with new shardings: ``reshard(tree, mesh, specs)``
places every leaf according to the new mesh — device counts may grow or
shrink between restarts (e.g. a pod lost to maintenance).  For live jobs
(no restart), ``reshard`` on the in-memory state performs the same move.
"""

from __future__ import annotations

from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P


def _is_spec_leaf(x: Any) -> bool:
    return x is None or isinstance(x, P)


def reshard(tree: Any, mesh: Mesh, spec_tree: Any) -> Any:
    """device_put every leaf with its (possibly new-mesh) PartitionSpec."""
    flat_t, treedef = jax.tree.flatten(tree)
    flat_s = jax.tree.flatten(spec_tree, is_leaf=_is_spec_leaf)[0]
    if len(flat_t) != len(flat_s):
        raise ValueError(f"tree/spec mismatch: {len(flat_t)} leaves vs "
                         f"{len(flat_s)} specs")
    out = []
    for x, spec in zip(flat_t, flat_s):
        if spec is None:
            spec = P(*([None] * getattr(x, "ndim", 0)))
        out.append(jax.device_put(x, NamedSharding(mesh, spec)))
    return treedef.unflatten(out)
