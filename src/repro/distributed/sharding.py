"""Per-family sharding rules (PartitionSpec trees) for the production mesh.

Mesh axes: ``("data", "model")`` single-pod, ``("pod", "data", "model")``
multi-pod (launch/mesh.py).  Batch always shards over ``("pod", "data")``;
weights shard over ``"model"`` following Megatron-style tensor parallelism
where divisibility allows, with documented fallbacks:

  * attention heads shard over model iff ``n_heads % model == 0`` (and KV
    heads shard with GSPMD padding whenever ``n_kv_heads >= 2``); archs
    with 12/15 heads keep attention weights replicated and rely on FFN TP
    (recorded per arch in EXPERIMENTS.md §Dry-run).
  * MoE experts shard over model on the expert axis (EP): 60 routed
    experts are padded to 64 slots (qwen2-moe) so EP=16 divides.
  * embedding/lm_head shard the vocab over model.
  * ZeRO-1: optimizer moments/master shard over "data" along each param's
    largest model-unsharded divisible axis (zero_opt_specs).
"""

from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.models.transformer import TransformerConfig


def shard_map(f, *, mesh: Mesh, in_specs, out_specs):
    """Version-portable shard_map with replication checking off.

    jax >= 0.6 exposes ``jax.shard_map(..., check_vma=False)``; older
    releases ship it as ``jax.experimental.shard_map`` with the flag named
    ``check_rep``.  Every caller in this repo wants the check disabled
    (psum-carrying train steps), so that's baked in.
    """
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
    from jax.experimental.shard_map import shard_map as _shard_map
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=False)


def axis_size(mesh: Mesh, name: str) -> int:
    return dict(zip(mesh.axis_names, mesh.devices.shape)).get(name, 1)


def batch_axes(mesh: Mesh):
    axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    return axes if axes else None


def named(mesh: Mesh, spec_tree: Any) -> Any:
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        spec_tree, is_leaf=lambda x: isinstance(x, P) or x is None)


# ---------------------------------------------------------------------------
# LM family
# ---------------------------------------------------------------------------

def lm_param_specs(cfg: TransformerConfig, mesh: Mesh) -> dict:
    # 4-D projections (L, d, H, dh): shard the HEAD axis when it divides
    # the model axis.  jit *arguments* must shard evenly (unlike
    # intermediates, which GSPMD pads), so odd head counts (smollm: 15,
    # qwen2-1.5b: 12 q / 2 kv) keep the (small) attention weights
    # replicated — the per-rank compute slicing still happens through the
    # attn_head_axis constraint on the q/k/v activations.
    m = axis_size(mesh, "model")
    q_ok = cfg.n_heads % m == 0
    kv_ok = cfg.n_kv_heads % m == 0
    attn_col_q = P(None, None, "model", None) if q_ok else P(None, None, None, None)
    attn_col_kv = P(None, None, "model", None) if kv_ok else P(None, None, None, None)
    attn_row = P(None, "model", None, None) if q_ok else P(None, None, None, None)
    attn_bias_q = P(None, "model", None) if q_ok else P(None, None, None)
    attn_bias_kv = P(None, "model", None) if kv_ok else P(None, None, None)

    layers: dict = {
        "attn_norm_scale": P(None, None),
        "ffn_norm_scale": P(None, None),
        "wq": attn_col_q, "wk": attn_col_kv, "wv": attn_col_kv,
        "wo": attn_row,
    }
    if cfg.norm == "layernorm":
        layers["attn_norm_bias"] = P(None, None)
        layers["ffn_norm_bias"] = P(None, None)
    if cfg.qkv_bias:
        layers["bq"] = attn_bias_q
        layers["bk"] = attn_bias_kv
        layers["bv"] = attn_bias_kv
    if cfg.moe:
        layers["router"] = P(None, None, None)
        layers["we_gate"] = P(None, "model", None, None)   # expert parallel
        layers["we_up"] = P(None, "model", None, None)
        layers["we_down"] = P(None, "model", None, None)
        if cfg.n_shared_experts:
            layers["ws_gate"] = P(None, None, "model")
            layers["ws_up"] = P(None, None, "model")
            layers["ws_down"] = P(None, "model", None)
            if cfg.shared_expert_gate:
                layers["shared_gate"] = P(None, None, None)
    else:
        layers["w_gate"] = P(None, None, "model")
        layers["w_up"] = P(None, None, "model")
        layers["w_down"] = P(None, "model", None)

    specs: dict = {
        "embed": P("model", None),
        "final_norm_scale": P(None),
        "layers": layers,
    }
    if cfg.norm == "layernorm":
        specs["final_norm_bias"] = P(None)
    if not cfg.tie_embeddings:
        specs["lm_head"] = P(None, "model")
    return specs


def lm_batch_spec(mesh: Mesh) -> P:
    return P(batch_axes(mesh), None)


def lm_cache_specs(cfg: TransformerConfig, mesh: Mesh, batch: int) -> dict:
    """KV cache: stacked [L, B, Smax, Hkv, dh] (scan mode) or a tuple of
    per-layer [B, Smax, Hkv, dh] buffers (unrolled mode)."""
    b_axes = batch_axes(mesh)
    total_b = 1
    for a in (b_axes or ()):
        total_b *= axis_size(mesh, a)
    b_ax = b_axes if (b_axes and batch % total_b == 0) else None
    # the cache is a jit *argument*: head axis shards only when it divides
    kv_ax = "model" if cfg.n_kv_heads % axis_size(mesh, "model") == 0 else None
    if cfg.unroll_layers:
        layer = P(b_ax, None, kv_ax, None)
        kv = tuple(layer for _ in range(cfg.n_layers))
    else:
        kv = P(None, b_ax, None, kv_ax, None)
    return {"k": kv, "v": kv, "len": P()}


# ---------------------------------------------------------------------------
# Streamed CSR shards (data/graph_stream.py)
# ---------------------------------------------------------------------------

def host_submesh(mesh: Optional[Mesh], process_index: int = 0,
                 process_count: int = 1) -> Optional[Mesh]:
    """One process's contiguous slice of the ``"data"`` axis.

    Multi-host streaming places each host's shards on the devices that
    host actually drives: the data axis is cut into ``process_count``
    equal runs and process ``i`` gets run ``i``.  Falls back to the full
    mesh when the axis is absent or does not divide (every process then
    places onto the shared mesh, which is also what the single-device
    in-process simulator exercises).
    """
    if mesh is None or process_count <= 1:
        return mesh
    if not 0 <= process_index < process_count:
        raise ValueError(
            f"process_index {process_index} not in [0, {process_count})")
    if "data" not in mesh.axis_names:
        return mesh
    d = axis_size(mesh, "data")
    if d % process_count:
        return mesh
    axis = list(mesh.axis_names).index("data")
    per = d // process_count
    idx = [slice(None)] * mesh.devices.ndim
    idx[axis] = slice(process_index * per, (process_index + 1) * per)
    return Mesh(mesh.devices[tuple(idx)], mesh.axis_names)


def stream_shard_placement(mesh: Optional[Mesh], n_edges: int, *,
                           process_index: int = 0, process_count: int = 1
                           ) -> tuple[Optional[NamedSharding],
                                      Optional[NamedSharding]]:
    """(neighbors, offsets) shardings for one streamed CSR partition.

    Neighbor arrays are the bulk payload and shard over the ``"data"`` axis
    (edge-parallel consumers: gather/segment-sum in models/gnn); the small
    per-partition offset arrays replicate.  device_put needs evenly
    divisible shards, so a partition whose edge count does not divide the
    data axis falls back to replication — the streaming loader pads to
    STREAM_GRANULE_IDS buckets precisely so the common case divides.

    ``process_index``/``process_count`` restrict placement to the calling
    host's :func:`host_submesh` slice of the data axis.
    """
    mesh = host_submesh(mesh, process_index, process_count)
    if mesh is None:
        return None, None
    axis = "data" if "data" in mesh.axis_names else None
    d = axis_size(mesh, axis) if axis else 1
    nbr_spec = P(axis) if axis and d > 1 and n_edges % d == 0 else P(None)
    return (NamedSharding(mesh, nbr_spec), NamedSharding(mesh, P(None)))


# ---------------------------------------------------------------------------
# GNN family
# ---------------------------------------------------------------------------

def gnn_specs(mesh: Mesh, batch_like: dict) -> dict:
    """Edge arrays shard over every axis (flattened); node/feature arrays
    shard rows over ("data", "model"); small per-graph arrays replicate."""
    all_axes = tuple(mesh.axis_names)
    node_axes = tuple(a for a in ("data", "model") if a in mesh.axis_names)

    def spec_for(key: str, arr) -> P:
        if key.startswith("edge_") or key.startswith("triplet_"):
            return P(all_axes) if arr.ndim == 1 else P(all_axes, None)
        if key in ("x", "pos"):
            return P(node_axes, None)
        if key in ("labels", "label_mask", "graph_id", "node_mask"):
            return P(node_axes)
        if key == "targets":
            return P(None, None) if arr.ndim == 2 else P(None)
        return P(*([None] * arr.ndim))

    return {k: spec_for(k, v) for k, v in batch_like.items()
            if hasattr(v, "ndim")}


# ---------------------------------------------------------------------------
# Recsys (DIN)
# ---------------------------------------------------------------------------

def din_param_specs(mesh: Mesh) -> dict:
    return {
        "item_table": P("model", None),   # 10M rows row-sharded
        "cate_table": P("model", None),
        "attn": None,                     # small MLPs replicated (filled below)
        "mlp": None,
    }


def din_specs(params_like: dict, mesh: Mesh) -> dict:
    specs = {
        "item_table": P("model", None),
        "cate_table": P("model", None),
        "attn": jax.tree.map(lambda x: P(*([None] * x.ndim)), params_like["attn"]),
        "mlp": jax.tree.map(lambda x: P(*([None] * x.ndim)), params_like["mlp"]),
    }
    return specs


def din_batch_specs(mesh: Mesh, batch_like: dict) -> dict:
    b_axes = batch_axes(mesh)
    all_axes = tuple(mesh.axis_names)

    def spec_for(key: str, arr) -> P:
        if key.startswith("cand_") and arr.ndim == 1 and arr.shape[0] >= 1024:
            # retrieval: candidate axis shards over everything
            return P(all_axes)
        if key.startswith("hist_") and arr.ndim == 1:
            # retrieval: one user's history, replicated
            return P(None)
        lead = b_axes
        return P(lead, *([None] * (arr.ndim - 1)))

    return {k: spec_for(k, v) for k, v in batch_like.items() if hasattr(v, "ndim")}


# ---------------------------------------------------------------------------
# ZeRO-1 optimizer-state sharding
# ---------------------------------------------------------------------------

def zero_spec(shape: tuple, spec: P, mesh: Mesh) -> P:
    """Add "data" sharding on the largest axis not already sharded."""
    d = axis_size(mesh, "data")
    entries = list(spec) + [None] * (len(shape) - len(spec))
    best, best_size = None, 0
    for i, (s, n) in enumerate(zip(entries, shape)):
        if s is None and n % d == 0 and n > best_size:
            best, best_size = i, n
    if best is not None:
        entries[best] = "data"
    return P(*entries)


def zero_opt_specs(params_like: Any, param_specs: Any, mesh: Mesh) -> dict:
    """Optimizer-state spec tree for optim.adamw state (step/m/v/master)."""
    flat_p, treedef = jax.tree.flatten(params_like)
    flat_s = jax.tree.flatten(param_specs,
                              is_leaf=lambda x: isinstance(x, P))[0]
    assert len(flat_p) == len(flat_s), (len(flat_p), len(flat_s))
    mv = treedef.unflatten(
        [zero_spec(p.shape, s, mesh) for p, s in zip(flat_p, flat_s)])
    return {"step": P(), "m": mv, "v": mv, "master": mv}
