"""stablelm-1.6b [hf:stabilityai/stablelm-2-1_6b]: 24L d_model=2048 32H
(kv=32, i.e. MHA) d_ff=5632 vocab=100352, LayerNorm, partial rotary 25%,
QKV bias."""

import jax.numpy as jnp

from repro.configs.base import ArchSpec
from repro.models.transformer import TransformerConfig


def make_config() -> TransformerConfig:
    return TransformerConfig(
        name="stablelm-1.6b", n_layers=24, d_model=2048, n_heads=32,
        n_kv_heads=32, d_head=64, d_ff=5632, vocab=100_352, max_seq=32_768,
        qkv_bias=True, norm="layernorm", rope_pct=0.25, dtype=jnp.bfloat16,
    )


def make_reduced() -> TransformerConfig:
    return TransformerConfig(
        name="stablelm-1.6b-reduced", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=4, d_head=16, d_ff=128, vocab=512, max_seq=128,
        qkv_bias=True, norm="layernorm", rope_pct=0.25, dtype=jnp.float32,
    )


SPEC = ArchSpec("stablelm-1.6b", "lm", "hf:stabilityai/stablelm-2-1_6b",
                make_config, make_reduced)
