"""dimenet [arXiv:2003.03123]: n_blocks=6 d_hidden=128 n_bilinear=8
n_spherical=7 n_radial=6 — directional (triplet) message passing."""

from repro.configs.base import ArchSpec
from repro.models.gnn.dimenet import DimeNetConfig


def make_config(d_in: int = 16, n_targets: int = 1) -> DimeNetConfig:
    return DimeNetConfig(name="dimenet", n_blocks=6, d_hidden=128,
                         n_bilinear=8, n_spherical=7, n_radial=6,
                         d_in=d_in, n_targets=n_targets)


def make_reduced() -> DimeNetConfig:
    return DimeNetConfig(name="dimenet-reduced", n_blocks=2, d_hidden=16,
                         n_bilinear=4, n_spherical=3, n_radial=3, d_in=8)


SPEC = ArchSpec("dimenet", "gnn", "arXiv:2003.03123", make_config, make_reduced)
