"""qwen2-moe-a2.7b [hf:Qwen/Qwen1.5-MoE-A2.7B]: 24L d_model=2048 16H
(GQA kv=16) moe_d_ff=1408 vocab=151936, MoE 60 routed top-4 + 4 shared
(shared intermediate 4x1408=5632, sigmoid shared-expert gate).  60 experts
pad to 64 physical slots so EP=16 divides (DESIGN.md §4)."""

import jax.numpy as jnp

from repro.configs.base import ArchSpec
from repro.models.transformer import TransformerConfig


def make_config() -> TransformerConfig:
    return TransformerConfig(
        name="qwen2-moe-a2.7b", n_layers=24, d_model=2048, n_heads=16,
        n_kv_heads=16, d_head=128, d_ff=0, vocab=151_936, max_seq=32_768,
        qkv_bias=True, norm="rmsnorm", rope_theta=1_000_000.0,
        moe=True, n_experts=60, n_experts_padded=64, top_k=4, moe_d_ff=1408,
        n_shared_experts=4, shared_d_ff=5632, shared_expert_gate=True,
        router_norm_topk=False, dtype=jnp.bfloat16,
    )


def make_reduced() -> TransformerConfig:
    return TransformerConfig(
        name="qwen2-moe-a2.7b-reduced", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=4, d_head=16, d_ff=0, vocab=512, max_seq=128,
        qkv_bias=True, norm="rmsnorm",
        moe=True, n_experts=6, n_experts_padded=8, top_k=4, moe_d_ff=32,
        n_shared_experts=2, shared_d_ff=64, shared_expert_gate=True,
        router_norm_topk=False, dtype=jnp.float32, capacity_factor=2.0,
    )


SPEC = ArchSpec("qwen2-moe-a2.7b", "lm", "hf:Qwen/Qwen1.5-MoE-A2.7B",
                make_config, make_reduced)
