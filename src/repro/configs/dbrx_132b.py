"""dbrx-132b [hf:databricks/dbrx-base]: 40L d_model=6144 48H (GQA kv=8)
d_ff=10752 (per expert) vocab=100352, MoE 16 experts top-4 (fine-grained),
normalized top-k router weights, LayerNorm."""

import jax.numpy as jnp

from repro.configs.base import ArchSpec
from repro.models.transformer import TransformerConfig


def make_config() -> TransformerConfig:
    return TransformerConfig(
        name="dbrx-132b", n_layers=40, d_model=6144, n_heads=48,
        n_kv_heads=8, d_head=128, d_ff=0, vocab=100_352, max_seq=32_768,
        qkv_bias=False, norm="layernorm", rope_theta=500_000.0,
        moe=True, n_experts=16, n_experts_padded=16, top_k=4, moe_d_ff=10_752,
        n_shared_experts=0, router_norm_topk=True, dtype=jnp.bfloat16,
    )


def make_reduced() -> TransformerConfig:
    return TransformerConfig(
        name="dbrx-132b-reduced", n_layers=2, d_model=64, n_heads=8,
        n_kv_heads=2, d_head=8, d_ff=0, vocab=512, max_seq=128,
        norm="layernorm", moe=True, n_experts=4, n_experts_padded=4,
        top_k=2, moe_d_ff=48, router_norm_topk=True, dtype=jnp.float32,
        capacity_factor=2.0,
    )


SPEC = ArchSpec("dbrx-132b", "lm", "hf:databricks/dbrx-base",
                make_config, make_reduced)
