"""qwen2-1.5b [arXiv:2407.10671]: 28L d_model=1536 12H (GQA kv=2)
d_ff=8960 vocab=151936, QKV bias, tied embeddings."""

import jax.numpy as jnp

from repro.configs.base import ArchSpec
from repro.models.transformer import TransformerConfig


def make_config() -> TransformerConfig:
    return TransformerConfig(
        name="qwen2-1.5b", n_layers=28, d_model=1536, n_heads=12,
        n_kv_heads=2, d_head=128, d_ff=8960, vocab=151_936, max_seq=32_768,
        qkv_bias=True, norm="rmsnorm", rope_theta=1_000_000.0,
        tie_embeddings=True, dtype=jnp.bfloat16,
    )


def make_reduced() -> TransformerConfig:
    return TransformerConfig(
        name="qwen2-1.5b-reduced", n_layers=2, d_model=48, n_heads=6,
        n_kv_heads=2, d_head=8, d_ff=128, vocab=512, max_seq=128,
        qkv_bias=True, norm="rmsnorm", tie_embeddings=True, dtype=jnp.float32,
    )


SPEC = ArchSpec("qwen2-1.5b", "lm", "arXiv:2407.10671", make_config, make_reduced)
