"""din [arXiv:1706.06978]: embed_dim=18 seq_len=100 attn_mlp=80-40
mlp=200-80, target-attention interaction.  Item catalog 10M rows,
10k categories (huge-sparse-embedding regime, row-sharded)."""

from repro.configs.base import ArchSpec
from repro.models.recsys.din import DINConfig


def make_config() -> DINConfig:
    return DINConfig(name="din", embed_dim=18, seq_len=100,
                     n_items=10_000_000, n_cates=10_000,
                     attn_mlp=(80, 40), mlp=(200, 80))


def make_reduced() -> DINConfig:
    return DINConfig(name="din-reduced", embed_dim=8, seq_len=20,
                     n_items=1000, n_cates=32, attn_mlp=(16, 8), mlp=(24, 12))


SPEC = ArchSpec("din", "recsys", "arXiv:1706.06978", make_config, make_reduced)
