"""Assigned input-shape sets, one per architecture family (40 cells).

Every cell resolves to a dict of ``jax.ShapeDtypeStruct`` (weak-type
correct, shardable, zero allocation) plus the entry point it lowers:
``train_step`` for training shapes, ``serve_step`` (prefill or decode) for
inference shapes.  LM ``long_500k`` is a recorded SKIP for all five
assigned LM archs — they are pure full-attention (GQA) models and the
shape requires sub-quadratic attention (DESIGN.md §5).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

I32 = jnp.int32
F32 = jnp.float32

# jit *arguments* must shard evenly; ragged graph sizes are padded up to
# the mesh-divisible multiple (node arrays shard over data x model = 256,
# edge/candidate arrays over every axis, <= 512 on the multi-pod mesh).
# Padding is semantic, not a hack: edge slots carry id -1 and are dropped
# by the segment ops; padded nodes receive zero features and no edges.
NODE_PAD = 256
EDGE_PAD = 512


def _pad(n: int, m: int) -> int:
    return -(-n // m) * m


def sds(shape, dtype=F32):
    return jax.ShapeDtypeStruct(tuple(int(x) for x in shape), dtype)


@dataclasses.dataclass(frozen=True)
class LMShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str               # "train" | "prefill" | "decode"
    skip_reason: Optional[str] = None


LM_SHAPES = {
    "train_4k": LMShape("train_4k", 4096, 256, "train"),
    "prefill_32k": LMShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": LMShape("decode_32k", 32768, 128, "decode"),
    "long_500k": LMShape(
        "long_500k", 524288, 1, "decode",
        skip_reason=("needs sub-quadratic attention; all five assigned LM "
                     "archs are pure full-attention (GQA) models — skip per "
                     "assignment rules, recorded in DESIGN.md §5")),
}


@dataclasses.dataclass(frozen=True)
class GNNShape:
    name: str
    n_nodes: int
    n_edges: int
    d_feat: int
    n_classes: int
    n_graphs: int = 1            # >1: batched small graphs (graph targets)
    triplet_factor: float = 4.0  # DimeNet triplet budget = factor * n_edges
    kind: str = "train"


GNN_SHAPES = {
    # Cora: full-batch semi-supervised node classification
    "full_graph_sm": GNNShape("full_graph_sm", 2708, 10556, 1433, 7),
    # Reddit-scale sampled training: padded 2-hop tree from the neighbor
    # sampler (batch_nodes=1024, fanout 15-10); d_feat=602 (Reddit).
    "minibatch_lg": GNNShape(
        "minibatch_lg",
        n_nodes=1024 + 1024 * 15 + 1024 * 150,
        n_edges=1024 * 15 + 1024 * 150,
        d_feat=602, n_classes=41),
    # ogbn-products full-batch
    "ogb_products": GNNShape("ogb_products", 2_449_029, 61_859_140, 100, 47),
    # batched small molecules: 128 graphs x 30 nodes / 64 edges.
    # dimenet: per-graph energy regression; gcn/pna/mgn: node-level heads
    # (atom-type classes) — documented in DESIGN.md §5.
    "molecule": GNNShape("molecule", 128 * 30, 128 * 64, 16, 16, n_graphs=128),
}

# source-graph metadata for the minibatch_lg sampler (Reddit)
MINIBATCH_SOURCE = {"n_nodes": 232_965, "n_edges": 114_615_892,
                    "batch_nodes": 1024, "fanout": (15, 10)}


@dataclasses.dataclass(frozen=True)
class RecsysShape:
    name: str
    batch: int
    kind: str                   # "train" | "serve" | "retrieval"
    n_candidates: int = 0


RECSYS_SHAPES = {
    "train_batch": RecsysShape("train_batch", 65_536, "train"),
    "serve_p99": RecsysShape("serve_p99", 512, "serve"),
    "serve_bulk": RecsysShape("serve_bulk", 262_144, "serve"),
    "retrieval_cand": RecsysShape("retrieval_cand", 1, "retrieval",
                                  n_candidates=1_000_000),
}


# ---------------------------------------------------------------------------
# input_specs builders (ShapeDtypeStruct only — never allocates)
# ---------------------------------------------------------------------------

def lm_input_specs(shape: LMShape, cfg) -> dict:
    B, S = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        return {"tokens": sds((B, S), I32), "labels": sds((B, S), I32)}
    if shape.kind == "prefill":
        return {"tokens": sds((B, S), I32)}
    # decode: one new token against a KV cache of seq_len.
    # unrolled mode uses a LAYERED cache (tuple of per-layer buffers).
    if cfg.unroll_layers:
        layer = sds((B, S, cfg.n_kv_heads, cfg.d_head), cfg.dtype)
        return {
            "tokens": sds((B, 1), I32),
            "cache_k": tuple(layer for _ in range(cfg.n_layers)),
            "cache_v": tuple(layer for _ in range(cfg.n_layers)),
            "cache_len": sds((), I32),
        }
    cache_shape = (cfg.n_layers, B, S, cfg.n_kv_heads, cfg.d_head)
    return {
        "tokens": sds((B, 1), I32),
        "cache_k": sds(cache_shape, cfg.dtype),
        "cache_v": sds(cache_shape, cfg.dtype),
        "cache_len": sds((), I32),
    }


def gnn_input_specs(shape: GNNShape, arch_id: str) -> dict:
    N, E = _pad(shape.n_nodes, NODE_PAD), _pad(shape.n_edges, EDGE_PAD)
    specs = {
        "x": sds((N, shape.d_feat), F32),
        "edge_src": sds((E,), I32),
        "edge_dst": sds((E,), I32),
    }
    if arch_id == "dimenet":
        T = _pad(int(shape.triplet_factor * E), EDGE_PAD)
        specs.update({
            "pos": sds((N, 3), F32),
            "triplet_kj": sds((T,), I32),
            "triplet_ji": sds((T,), I32),
            "graph_id": sds((N,), I32),
            "targets": sds((shape.n_graphs, 1), F32),
        })
    elif arch_id == "meshgraphnet":
        specs.update({
            "edge_attr": sds((E, 8), F32),
            "targets": sds((N, 3), F32),
            "node_mask": sds((N,), jnp.bool_),
        })
    else:  # gcn / pna: node classification
        specs.update({
            "labels": sds((N,), I32),
            "label_mask": sds((N,), jnp.bool_),
        })
    return specs


def din_input_specs(shape: RecsysShape, cfg) -> dict:
    S = cfg.seq_len
    if shape.kind == "retrieval":
        n_cand = _pad(shape.n_candidates, EDGE_PAD)
        return {
            "hist_items": sds((S,), I32), "hist_cates": sds((S,), I32),
            "cand_items": sds((n_cand,), I32),
            "cand_cates": sds((n_cand,), I32),
        }
    B = shape.batch
    specs = {
        "hist_items": sds((B, S), I32), "hist_cates": sds((B, S), I32),
        "cand_item": sds((B,), I32), "cand_cate": sds((B,), I32),
    }
    if shape.kind == "train":
        specs["labels"] = sds((B,), F32)
    return specs
