"""ArchSpec: one assigned architecture = full config + reduced smoke config
+ its shape catalog."""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

from repro.configs import shapes as sh


@dataclasses.dataclass(frozen=True)
class ArchSpec:
    arch_id: str
    family: str                      # "lm" | "gnn" | "recsys"
    citation: str
    make_config: Callable[[], Any]   # full (paper-exact) config
    make_reduced: Callable[[], Any]  # tiny same-family config for CPU smoke

    @property
    def shapes(self) -> dict:
        return {"lm": sh.LM_SHAPES, "gnn": sh.GNN_SHAPES,
                "recsys": sh.RECSYS_SHAPES}[self.family]
