"""gcn-cora [arXiv:1609.02907]: n_layers=2 d_hidden=16 sym-normalized
aggregation — the canonical Cora full-batch config."""

from repro.configs.base import ArchSpec
from repro.models.gnn.gcn import GCNConfig


def make_config(d_in: int = 1433, n_classes: int = 7) -> GCNConfig:
    return GCNConfig(name="gcn-cora", n_layers=2, d_hidden=16, d_in=d_in,
                     n_classes=n_classes, norm="sym")


def make_reduced() -> GCNConfig:
    return GCNConfig(name="gcn-cora-reduced", n_layers=2, d_hidden=8,
                     d_in=16, n_classes=4, norm="sym")


SPEC = ArchSpec("gcn-cora", "gnn", "arXiv:1609.02907", make_config, make_reduced)
