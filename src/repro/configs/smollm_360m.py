"""smollm-360m [hf:HuggingFaceTB/SmolLM-360M]: llama-arch small —
32L d_model=960 15H (GQA kv=5) d_ff=2560 vocab=49152, tied embeddings."""

import jax.numpy as jnp

from repro.configs.base import ArchSpec
from repro.models.transformer import TransformerConfig


def make_config() -> TransformerConfig:
    return TransformerConfig(
        name="smollm-360m", n_layers=32, d_model=960, n_heads=15,
        n_kv_heads=5, d_head=64, d_ff=2560, vocab=49_152, max_seq=32_768,
        norm="rmsnorm", tie_embeddings=True, dtype=jnp.bfloat16,
    )


def make_reduced() -> TransformerConfig:
    return TransformerConfig(
        name="smollm-360m-reduced", n_layers=2, d_model=48, n_heads=3,
        n_kv_heads=1, d_head=16, d_ff=128, vocab=512, max_seq=128,
        norm="rmsnorm", tie_embeddings=True, dtype=jnp.float32,
    )


SPEC = ArchSpec("smollm-360m", "lm", "hf:HuggingFaceTB/SmolLM-360M",
                make_config, make_reduced)
