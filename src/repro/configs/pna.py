"""pna [arXiv:2004.05718]: n_layers=4 d_hidden=75, aggregators
mean-max-min-std, scalers identity-amplification-attenuation."""

from repro.configs.base import ArchSpec
from repro.models.gnn.pna import PNAConfig


def make_config(d_in: int = 64, n_classes: int = 10) -> PNAConfig:
    return PNAConfig(name="pna", n_layers=4, d_hidden=75, d_in=d_in,
                     n_classes=n_classes)


def make_reduced() -> PNAConfig:
    return PNAConfig(name="pna-reduced", n_layers=2, d_hidden=12, d_in=8,
                     n_classes=4)


SPEC = ArchSpec("pna", "gnn", "arXiv:2004.05718", make_config, make_reduced)
