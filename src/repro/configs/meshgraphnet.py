"""meshgraphnet [arXiv:2010.03409]: n_layers=15 d_hidden=128 sum
aggregation, 2-layer MLPs — encode-process-decode mesh simulation."""

from repro.configs.base import ArchSpec
from repro.models.gnn.meshgraphnet import MeshGraphNetConfig


def make_config(d_node_in: int = 16, d_edge_in: int = 8) -> MeshGraphNetConfig:
    return MeshGraphNetConfig(name="meshgraphnet", n_layers=15, d_hidden=128,
                              mlp_layers=2, d_node_in=d_node_in,
                              d_edge_in=d_edge_in, d_out=3)


def make_reduced() -> MeshGraphNetConfig:
    return MeshGraphNetConfig(name="meshgraphnet-reduced", n_layers=2,
                              d_hidden=16, mlp_layers=2, d_node_in=8,
                              d_edge_in=4, d_out=3)


SPEC = ArchSpec("meshgraphnet", "gnn", "arXiv:2010.03409",
                make_config, make_reduced)
