"""Architecture registry: ``--arch <id>`` resolution for every launcher."""

from __future__ import annotations

from repro.configs import (dbrx_132b, dimenet, din, gcn_cora,  # noqa: F401
                           meshgraphnet, pna, qwen2_1_5b, qwen2_moe_a2_7b,
                           shapes, smollm_360m, stablelm_1_6b)
from repro.configs.base import ArchSpec

_MODULES = [qwen2_moe_a2_7b, dbrx_132b, smollm_360m, qwen2_1_5b,
            stablelm_1_6b, dimenet, meshgraphnet, gcn_cora, pna, din]

REGISTRY: dict[str, ArchSpec] = {m.SPEC.arch_id: m.SPEC for m in _MODULES}

ARCH_IDS = list(REGISTRY)


def get_arch(arch_id: str) -> ArchSpec:
    if arch_id not in REGISTRY:
        raise KeyError(f"unknown arch {arch_id!r}; available: {ARCH_IDS}")
    return REGISTRY[arch_id]


def all_cells() -> list[tuple[str, str]]:
    """All (arch, shape) cells of the assignment — 40 in total."""
    out = []
    for arch_id, spec in REGISTRY.items():
        for shape_id in spec.shapes:
            out.append((arch_id, shape_id))
    return out
